//! Hashing and pseudo-randomness substrate.
//!
//! CommonSense's CS matrix is *implicit* (Definition 6 of the paper): column `i` of `M` is
//! `g(h(i))` where `h` maps ids to uniform integers and `g` enumerates m-subsets of the l
//! rows. We realize `g∘h` with a per-element seeded PRNG and Floyd's subset sampling, which
//! costs O(m) per column — matching the complexity the paper's Theorem 2 relies on.
//!
//! Everything here is deterministic given seeds, so Alice and Bob derive identical matrices
//! from a shared `(seed, l, m)` triple, and experiments are exactly reproducible.

mod column;
mod idmap;
mod prng;
mod sha256;
mod siphash;

pub use column::{ColumnSampler, GeometryError, MAX_M};
pub use idmap::IdIndex;
pub use prng::{split_mix64, Xoshiro256};
pub use sha256::{sha256, Sha256};
pub use siphash::SipHash13;

/// A 64-bit mixing finalizer (Murmur3/SplitMix style). Used wherever a cheap, well-mixed
/// keyed hash of a 64-bit id is needed (Bloom filters, IBLT cells, partitioning).
#[inline]
pub fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Keyed 64-bit hash of an id: `mix64` over id xor a seed-derived constant.
///
/// This is *not* cryptographic; it is the workhorse for index derivation in filters and
/// sketches, where only uniformity matters.
#[inline]
pub fn hash_u64(id: u64, seed: u64) -> u64 {
    mix64(id ^ split_mix64(seed))
}

/// Derive `k` hash values for an id from two base hashes (Kirsch–Mitzenmacher double
/// hashing), the standard trick Bloom-family filters use to avoid k independent hashes.
#[inline]
pub fn double_hash(id: u64, seed: u64, k: u32, modulus: u64) -> impl Iterator<Item = u64> {
    let h1 = hash_u64(id, seed);
    let h2 = hash_u64(id, seed ^ 0x9e37_79b9_7f4a_7c15) | 1; // odd ⇒ full period
    (0..k as u64).map(move |i| {
        let h = h1.wrapping_add(i.wrapping_mul(h2));
        // Lemire's multiply-shift range reduction: unbiased enough for filters, branch-free.
        ((h as u128 * modulus as u128) >> 64) as u64
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_bijective_on_samples() {
        // Spot-check injectivity and avalanche on a few thousand inputs.
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(mix64(i)));
        }
    }

    #[test]
    fn hash_u64_depends_on_seed() {
        assert_ne!(hash_u64(42, 1), hash_u64(42, 2));
    }

    #[test]
    fn double_hash_in_range_and_spread() {
        let modulus = 997;
        let mut counts = vec![0u32; modulus as usize];
        for id in 0..10_000u64 {
            for h in double_hash(id, 7, 4, modulus) {
                assert!(h < modulus);
                counts[h as usize] += 1;
            }
        }
        // 40_000 draws over 997 buckets: mean ≈ 40.1. No bucket should be empty or wildly hot.
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(*min > 10, "min bucket {min}");
        assert!(*max < 120, "max bucket {max}");
    }
}
