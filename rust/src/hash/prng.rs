//! Seeded PRNGs: SplitMix64 (seeding / stateless mixing) and xoshiro256** (bulk generation).
//!
//! We implement these from scratch so the library has zero RNG dependencies and both sides of
//! the protocol (and every experiment) are bit-reproducible from a `u64` seed.

/// One step of SplitMix64 treated as a stateless hash of the input.
#[inline]
pub fn split_mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via four SplitMix64 steps (the construction recommended by the authors).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut x = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            *slot = split_mix64(x);
        }
        // All-zero state is invalid (fixed point); SplitMix64 of distinct inputs can't
        // produce it, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Xoshiro256 { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (with rejection for exactness).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = x as u128 * bound as u128;
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                // Accept unless in the biased low fringe.
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform f64 in [0,1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample a Poisson(λ) variate. Knuth's method for small λ, normal approximation with
    /// continuity correction (clamped) for large λ — adequate for workload generation.
    pub fn gen_poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let limit = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.gen_f64();
                if p <= limit {
                    return k;
                }
                k += 1;
            }
        } else {
            // Box-Muller normal.
            let u1 = self.gen_f64().max(1e-12);
            let u2 = self.gen_f64();
            let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            let v = lambda + lambda.sqrt() * z;
            v.max(0.0).round() as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut b = Xoshiro256::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_roughly_uniform() {
        let mut r = Xoshiro256::seed_from_u64(4);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.gen_range(8) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Xoshiro256::seed_from_u64(5);
        for lambda in [0.5, 5.0, 80.0] {
            let n = 20_000;
            let sum: u64 = (0..n).map(|_| r.gen_poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < 0.1 * lambda + 0.1,
                "λ={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely to be identity
    }
}
