//! `IdIndex` — an open-addressing id → index table (FxHashMap-shaped, dependency-free).
//!
//! The MP decoder needs to answer "which candidate slot holds element id `x`?" on every
//! `force` call (one per §5.2 inquiry/answer, so O(d) of them per ping-pong round). A
//! linear scan over the candidate vector makes that O(n) per call — the exact landmine
//! this table removes. Linear-probed open addressing at load factor ≤ 0.5 answers in O(1)
//! expected probes, the table is built once per decoder construction, and the layout is
//! two flat arrays (keys + values), so lookups are one hash plus a short cache-friendly
//! probe run.
//!
//! Values are `u32` slot indices; `u32::MAX` is reserved as the empty marker, which caps
//! indexable collections at `u32::MAX - 1` entries — far beyond any candidate set this
//! repo runs (and asserted at build time).

use super::mix64;

/// Empty-slot marker in the value array (keys are irrelevant where this appears).
const EMPTY: u32 = u32::MAX;

/// Immutable open-addressing map from `u64` ids to `u32` indices.
///
/// Built once from a slice of ids (`build`); duplicate ids keep the *first* index, which
/// matches `ids.iter().position(..)` semantics the decoder previously relied on.
#[derive(Clone, Debug)]
pub struct IdIndex {
    /// Power-of-two capacity minus one (probe mask).
    mask: usize,
    keys: Vec<u64>,
    vals: Vec<u32>,
    len: usize,
}

impl IdIndex {
    /// Build the table over `ids[i] → i`. O(n) expected; capacity is the smallest power
    /// of two giving load factor ≤ 0.5.
    pub fn build(ids: &[u64]) -> IdIndex {
        assert!(
            (ids.len() as u64) < EMPTY as u64,
            "IdIndex supports at most 2^32 - 1 entries (got {})",
            ids.len()
        );
        let cap = (ids.len().max(4) * 2).next_power_of_two();
        let mut index = IdIndex {
            mask: cap - 1,
            keys: vec![0u64; cap],
            vals: vec![EMPTY; cap],
            len: 0,
        };
        for (i, &id) in ids.iter().enumerate() {
            index.insert_first_wins(id, i as u32);
        }
        index
    }

    #[inline]
    fn slot_of(&self, id: u64) -> usize {
        mix64(id) as usize & self.mask
    }

    fn insert_first_wins(&mut self, id: u64, val: u32) {
        let mut slot = self.slot_of(id);
        loop {
            if self.vals[slot] == EMPTY {
                self.keys[slot] = id;
                self.vals[slot] = val;
                self.len += 1;
                return;
            }
            if self.keys[slot] == id {
                // Duplicate id: keep the first index (position() semantics).
                return;
            }
            slot = (slot + 1) & self.mask;
        }
    }

    /// Index of `id`, if present. O(1) expected probes at ≤ 0.5 load.
    #[inline]
    pub fn get(&self, id: u64) -> Option<u32> {
        let mut slot = self.slot_of(id);
        loop {
            let v = self.vals[slot];
            if v == EMPTY {
                return None;
            }
            if self.keys[slot] == id {
                return Some(v);
            }
            slot = (slot + 1) & self.mask;
        }
    }

    /// `get` plus the number of slots probed — the observable that lets tests assert the
    /// O(1)-per-lookup property instead of wall-clock timing.
    pub fn get_probed(&self, id: u64) -> (Option<u32>, usize) {
        let mut slot = self.slot_of(id);
        let mut probes = 1usize;
        loop {
            let v = self.vals[slot];
            if v == EMPTY {
                return (None, probes);
            }
            if self.keys[slot] == id {
                return (Some(v), probes);
            }
            slot = (slot + 1) & self.mask;
            probes += 1;
        }
    }

    /// Distinct ids stored.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_every_id_to_its_slot() {
        let ids: Vec<u64> = (0..10_000u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)).collect();
        let idx = IdIndex::build(&ids);
        assert_eq!(idx.len(), ids.len());
        for (i, &id) in ids.iter().enumerate() {
            assert_eq!(idx.get(id), Some(i as u32));
        }
    }

    #[test]
    fn misses_return_none() {
        let ids: Vec<u64> = (0..1000u64).map(|i| i * 3).collect();
        let idx = IdIndex::build(&ids);
        assert_eq!(idx.get(1), None);
        assert_eq!(idx.get(u64::MAX), None);
    }

    #[test]
    fn duplicates_keep_first_index() {
        let idx = IdIndex::build(&[7, 8, 7, 9]);
        assert_eq!(idx.get(7), Some(0));
        assert_eq!(idx.get(9), Some(3));
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn empty_and_tiny_tables_work() {
        let idx = IdIndex::build(&[]);
        assert!(idx.is_empty());
        assert_eq!(idx.get(0), None);
        let one = IdIndex::build(&[0]);
        assert_eq!(one.get(0), Some(0));
    }

    #[test]
    fn probe_counts_stay_constant_at_half_load() {
        let ids: Vec<u64> = (0..50_000u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ 0xabcd).collect();
        let idx = IdIndex::build(&ids);
        let mut total = 0usize;
        for &id in &ids {
            let (hit, probes) = idx.get_probed(id);
            assert!(hit.is_some());
            total += probes;
        }
        // Expected probes ≈ 1.5 at load 0.5; a linear scan would average n/2 = 25_000.
        assert!(total < 4 * ids.len(), "avg probes {:.2}", total as f64 / ids.len() as f64);
    }
}
