//! SipHash-1-3 — a fast keyed PRF over byte strings.
//!
//! Used where we hash *variable-length* content (file chunks, transactions, account tuples)
//! down to 64-bit ids. SipHash-1-3 is the variant used by most hash-table implementations;
//! it is keyed, so distinct experiment seeds induce independent id spaces.

#[derive(Clone, Copy, Debug)]
pub struct SipHash13 {
    k0: u64,
    k1: u64,
}

#[inline]
fn sipround(v: &mut [u64; 4]) {
    v[0] = v[0].wrapping_add(v[1]);
    v[1] = v[1].rotate_left(13);
    v[1] ^= v[0];
    v[0] = v[0].rotate_left(32);
    v[2] = v[2].wrapping_add(v[3]);
    v[3] = v[3].rotate_left(16);
    v[3] ^= v[2];
    v[0] = v[0].wrapping_add(v[3]);
    v[3] = v[3].rotate_left(21);
    v[3] ^= v[0];
    v[2] = v[2].wrapping_add(v[1]);
    v[1] = v[1].rotate_left(17);
    v[1] ^= v[2];
    v[2] = v[2].rotate_left(32);
}

impl SipHash13 {
    pub fn new(k0: u64, k1: u64) -> Self {
        SipHash13 { k0, k1 }
    }

    /// Derive a keyed instance from a single seed.
    pub fn from_seed(seed: u64) -> Self {
        SipHash13 {
            k0: super::split_mix64(seed),
            k1: super::split_mix64(seed ^ 0xdead_beef_cafe_f00d),
        }
    }

    /// Hash a byte string to 64 bits.
    pub fn hash(&self, data: &[u8]) -> u64 {
        let mut v = [
            self.k0 ^ 0x736f_6d65_7073_6575,
            self.k1 ^ 0x646f_7261_6e64_6f6d,
            self.k0 ^ 0x6c79_6765_6e65_7261,
            self.k1 ^ 0x7465_6462_7974_6573,
        ];
        let len = data.len();
        let mut chunks = data.chunks_exact(8);
        for chunk in &mut chunks {
            let m = u64::from_le_bytes(chunk.try_into().unwrap());
            v[3] ^= m;
            sipround(&mut v);
            v[0] ^= m;
        }
        let rem = chunks.remainder();
        let mut last = (len as u64 & 0xff) << 56;
        for (i, &b) in rem.iter().enumerate() {
            last |= (b as u64) << (8 * i);
        }
        v[3] ^= last;
        sipround(&mut v);
        v[0] ^= last;
        v[2] ^= 0xff;
        for _ in 0..3 {
            sipround(&mut v);
        }
        v[0] ^ v[1] ^ v[2] ^ v[3]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_keyed() {
        let h1 = SipHash13::new(1, 2);
        let h2 = SipHash13::new(1, 2);
        let h3 = SipHash13::new(3, 4);
        assert_eq!(h1.hash(b"hello"), h2.hash(b"hello"));
        assert_ne!(h1.hash(b"hello"), h3.hash(b"hello"));
        assert_ne!(h1.hash(b"hello"), h1.hash(b"hellp"));
    }

    #[test]
    fn length_extension_distinct() {
        // Same prefix, different lengths must hash differently (length is folded in).
        let h = SipHash13::from_seed(9);
        assert_ne!(h.hash(b""), h.hash(b"\0"));
        assert_ne!(h.hash(b"aaaaaaa"), h.hash(b"aaaaaaaa"));
    }

    #[test]
    fn uniformity_smoke() {
        let h = SipHash13::from_seed(1234);
        let n = 50_000u64;
        let mut buckets = [0u32; 16];
        for i in 0..n {
            buckets[(h.hash(&i.to_le_bytes()) >> 60) as usize] += 1;
        }
        for b in buckets {
            let expect = n as f64 / 16.0;
            assert!((b as f64 - expect).abs() < 0.1 * expect, "bucket {b}");
        }
    }
}
