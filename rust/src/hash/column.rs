//! The `g∘h` implicit column construction of Definition 6.
//!
//! Column `i` of the CS matrix `M` is a length-`l` 0/1 vector with exactly `m` ones at
//! pseudo-random distinct rows, derived deterministically from `(seed, i)`. The paper requires
//! O(m) evaluation time for the encoding complexity of Theorem 2 to hold; we use Floyd's
//! subset-sampling algorithm, which draws exactly `m` distinct values in `m` PRNG steps.

use super::prng::{split_mix64, Xoshiro256};

/// Largest supported column degree.
///
/// This is a **load-bearing invariant**, not a tuning knob: the streaming hot paths
/// (`Sketch::update`, `Residue::add_column`, `Residue::dot_column`) sample columns into a
/// `[u32; MAX_M as usize]` stack buffer, so an `m` beyond this bound would slice out of
/// range deep inside those loops. Every `ColumnSampler` therefore rejects `m > MAX_M` at
/// construction time — with a hard assert in [`ColumnSampler::new`] and a typed
/// [`GeometryError`] in [`ColumnSampler::try_new`] for untrusted (wire-derived) geometry.
/// The paper runs m ∈ {5, 7}; 64 is far above anything the tuning ever picks.
pub const MAX_M: u32 = 64;

/// Rejected CS-matrix geometry — the typed counterpart of the [`ColumnSampler::new`]
/// assertions, for paths (wire `Hello` frames, config parsing) where a panic is not
/// acceptable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeometryError {
    /// `m` must be at least 1 (a zero-degree column measures nothing).
    ZeroM,
    /// `m` exceeds the stack-buffer bound [`MAX_M`].
    MTooLarge { m: u32 },
    /// A column cannot have more distinct rows than the matrix has rows.
    MExceedsL { m: u32, l: u32 },
}

impl std::fmt::Display for GeometryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GeometryError::ZeroM => write!(f, "column degree m must be >= 1"),
            GeometryError::MTooLarge { m } => {
                write!(f, "column degree m={m} exceeds MAX_M={MAX_M}")
            }
            GeometryError::MExceedsL { m, l } => {
                write!(f, "column degree m={m} exceeds row count l={l}")
            }
        }
    }
}

impl std::error::Error for GeometryError {}

/// Deterministic sampler of m distinct rows in `[0, l)` per element id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ColumnSampler {
    /// Number of rows of the CS matrix.
    pub l: u32,
    /// Ones per column (right-degree of the bipartite expander); always ≤ [`MAX_M`].
    pub m: u32,
    /// Shared seed; Alice and Bob must agree on it.
    pub seed: u64,
}

impl ColumnSampler {
    /// Construct a sampler, panicking on invalid geometry. Use [`Self::try_new`] when the
    /// parameters come from the wire or any other untrusted source.
    pub fn new(l: u32, m: u32, seed: u64) -> Self {
        match Self::try_new(l, m, seed) {
            Ok(s) => s,
            Err(e) => panic!("invalid CS-matrix geometry: {e}"),
        }
    }

    /// Construct a sampler, rejecting invalid geometry with a typed [`GeometryError`].
    /// This is the single validation point for the `m ≤ MAX_M` stack-buffer invariant:
    /// no `ColumnSampler` (hence no `CsMatrix`, hence no `Sketch`) with `m > MAX_M` can
    /// exist, so the fixed-size buffers in the streaming hot paths never overflow —
    /// in release builds included.
    pub fn try_new(l: u32, m: u32, seed: u64) -> Result<Self, GeometryError> {
        if m == 0 {
            return Err(GeometryError::ZeroM);
        }
        if m > MAX_M {
            return Err(GeometryError::MTooLarge { m });
        }
        if m > l {
            return Err(GeometryError::MExceedsL { m, l });
        }
        Ok(ColumnSampler { l, m, seed })
    }

    /// Write the m distinct row indices of column `id` into `out` (must have length >= m).
    /// Returns the filled slice. Rows are *not* sorted (callers that need order sort once).
    ///
    /// Floyd's algorithm: for j = l-m .. l-1, draw t ∈ [0, j]; insert t unless already
    /// present, else insert j. Membership over ≤ m=O(log) items is a linear scan — faster
    /// than any set structure at this size.
    #[inline]
    pub fn rows_into<'a>(&self, id: u64, out: &'a mut [u32]) -> &'a [u32] {
        debug_assert!(out.len() >= self.m as usize);
        self.rows_into_mixed(split_mix64(self.seed), id, out);
        &out[..self.m as usize]
    }

    /// The shared Floyd kernel: `seed_mix` is the pre-mixed `split_mix64(self.seed)`, so
    /// batch callers hoist that half of the PRNG seeding out of their per-element loop.
    /// [`rows_into`](Self::rows_into) and [`rows_batch`](Self::rows_batch) both funnel
    /// through here — they cannot drift apart.
    #[inline]
    fn rows_into_mixed(&self, seed_mix: u64, id: u64, out: &mut [u32]) {
        let mut rng = Xoshiro256::seed_from_u64(seed_mix ^ split_mix64(id));
        let mut count = 0usize;
        let start = self.l - self.m;
        for j in start..self.l {
            let t = rng.gen_range(j as u64 + 1) as u32;
            let pick = if out[..count].contains(&t) { j } else { t };
            out[count] = pick;
            count += 1;
        }
    }

    /// Batched [`rows_into`](Self::rows_into): sample the columns of every id in `ids` in
    /// one call, writing column `i` into `out[i*m .. (i+1)*m]` (`out.len()` must be at
    /// least `ids.len() * m`). Bit-identical to calling `rows_into` per id — same Floyd
    /// draws from the same per-id PRNG stream — but the seed pre-mix, the bounds checks,
    /// and the call overhead are hoisted out of the per-element loop, which is what the
    /// encode hot path ([`crate::sketch::Sketch::encode`]) iterates millions of times.
    pub fn rows_batch(&self, ids: &[u64], out: &mut [u32]) {
        let m = self.m as usize;
        assert!(
            out.len() >= ids.len() * m,
            "rows_batch out buffer too small: {} < {}",
            out.len(),
            ids.len() * m
        );
        let seed_mix = split_mix64(self.seed);
        for (col, &id) in out.chunks_exact_mut(m).zip(ids) {
            self.rows_into_mixed(seed_mix, id, col);
        }
    }

    /// Allocate-and-return variant of [`rows_into`](Self::rows_into).
    pub fn rows(&self, id: u64) -> Vec<u32> {
        let mut out = vec![0u32; self.m as usize];
        self.rows_into(id, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_distinct_and_in_range() {
        let s = ColumnSampler::new(1000, 7, 42);
        for id in 0..2000u64 {
            let rows = s.rows(id);
            assert_eq!(rows.len(), 7);
            let mut sorted = rows.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 7, "duplicate rows for id {id}");
            assert!(rows.iter().all(|&r| r < 1000));
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let s1 = ColumnSampler::new(512, 5, 7);
        let s2 = ColumnSampler::new(512, 5, 7);
        for id in [0u64, 1, u64::MAX, 123456789] {
            assert_eq!(s1.rows(id), s2.rows(id));
        }
    }

    #[test]
    fn seed_changes_columns() {
        let s1 = ColumnSampler::new(512, 5, 1);
        let s2 = ColumnSampler::new(512, 5, 2);
        let differs = (0..100u64).any(|id| s1.rows(id) != s2.rows(id));
        assert!(differs);
    }

    #[test]
    fn try_new_rejects_bad_geometry_with_typed_errors() {
        assert_eq!(ColumnSampler::try_new(100, 0, 1), Err(GeometryError::ZeroM));
        assert_eq!(
            ColumnSampler::try_new(1 << 20, MAX_M + 1, 1),
            Err(GeometryError::MTooLarge { m: MAX_M + 1 })
        );
        assert_eq!(
            ColumnSampler::try_new(4, 5, 1),
            Err(GeometryError::MExceedsL { m: 5, l: 4 })
        );
        // The boundary itself is legal.
        assert!(ColumnSampler::try_new(1 << 20, MAX_M, 1).is_ok());
    }

    #[test]
    #[should_panic(expected = "invalid CS-matrix geometry")]
    fn new_panics_on_m_beyond_stack_buffer() {
        // This used to be a debug_assert! deep in Sketch::update — release builds would
        // sail past it and panic on a slice inside the hot loop instead.
        let _ = ColumnSampler::new(1 << 20, MAX_M + 1, 1);
    }

    #[test]
    fn rows_batch_is_bit_identical_to_rows_into() {
        // Property: across geometries (including the MAX_M boundary and m == l), the
        // batched sampler writes exactly the per-id rows, in the same order.
        let mut rng_seed = 0x5eedu64;
        let geoms = [(1000u32, 7u32), (512, 5), (64, 64), (1 << 16, MAX_M), (5, 5), (128, 1)];
        for &(l, m) in &geoms {
            rng_seed = rng_seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            let s = ColumnSampler::new(l, m, rng_seed);
            let ids: Vec<u64> =
                (0..257u64).map(|i| i.wrapping_mul(0x9e3779b97f4a7c15) ^ rng_seed).collect();
            let mut batch = vec![0u32; ids.len() * m as usize];
            s.rows_batch(&ids, &mut batch);
            for (i, &id) in ids.iter().enumerate() {
                let col = &batch[i * m as usize..(i + 1) * m as usize];
                assert_eq!(col, &s.rows(id)[..], "l={l} m={m} id={id}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "rows_batch out buffer too small")]
    fn rows_batch_rejects_short_buffer() {
        let s = ColumnSampler::new(128, 5, 1);
        let mut out = vec![0u32; 9]; // 2 ids need 10
        s.rows_batch(&[1, 2], &mut out);
    }

    #[test]
    fn m_equals_l_is_all_rows() {
        let s = ColumnSampler::new(5, 5, 3);
        let mut rows = s.rows(99);
        rows.sort_unstable();
        assert_eq!(rows, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rows_roughly_uniform_over_l() {
        let s = ColumnSampler::new(128, 4, 11);
        let mut counts = vec![0u32; 128];
        for id in 0..20_000u64 {
            for &r in &s.rows(id) {
                counts[r as usize] += 1;
            }
        }
        // 80_000 placements over 128 rows: mean 625.
        for (r, &c) in counts.iter().enumerate() {
            assert!((450..800).contains(&c), "row {r} count {c}");
        }
    }
}
