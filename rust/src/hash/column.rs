//! The `g∘h` implicit column construction of Definition 6.
//!
//! Column `i` of the CS matrix `M` is a length-`l` 0/1 vector with exactly `m` ones at
//! pseudo-random distinct rows, derived deterministically from `(seed, i)`. The paper requires
//! O(m) evaluation time for the encoding complexity of Theorem 2 to hold; we use Floyd's
//! subset-sampling algorithm, which draws exactly `m` distinct values in `m` PRNG steps.

use super::prng::{split_mix64, Xoshiro256};

/// Deterministic sampler of m distinct rows in `[0, l)` per element id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ColumnSampler {
    /// Number of rows of the CS matrix.
    pub l: u32,
    /// Ones per column (right-degree of the bipartite expander).
    pub m: u32,
    /// Shared seed; Alice and Bob must agree on it.
    pub seed: u64,
}

impl ColumnSampler {
    pub fn new(l: u32, m: u32, seed: u64) -> Self {
        assert!(m >= 1 && (m as u64) <= l as u64, "need 1 <= m <= l (m={m}, l={l})");
        ColumnSampler { l, m, seed }
    }

    /// Write the m distinct row indices of column `id` into `out` (must have length >= m).
    /// Returns the filled slice. Rows are *not* sorted (callers that need order sort once).
    ///
    /// Floyd's algorithm: for j = l-m .. l-1, draw t ∈ [0, j]; insert t unless already
    /// present, else insert j. Membership over ≤ m=O(log) items is a linear scan — faster
    /// than any set structure at this size.
    #[inline]
    pub fn rows_into<'a>(&self, id: u64, out: &'a mut [u32]) -> &'a [u32] {
        debug_assert!(out.len() >= self.m as usize);
        let mut rng = Xoshiro256::seed_from_u64(split_mix64(self.seed) ^ split_mix64(id));
        let mut count = 0usize;
        let start = self.l - self.m;
        for j in start..self.l {
            let t = rng.gen_range(j as u64 + 1) as u32;
            let pick = if out[..count].contains(&t) { j } else { t };
            out[count] = pick;
            count += 1;
        }
        &out[..self.m as usize]
    }

    /// Allocate-and-return variant of [`rows_into`](Self::rows_into).
    pub fn rows(&self, id: u64) -> Vec<u32> {
        let mut out = vec![0u32; self.m as usize];
        self.rows_into(id, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_distinct_and_in_range() {
        let s = ColumnSampler::new(1000, 7, 42);
        for id in 0..2000u64 {
            let rows = s.rows(id);
            assert_eq!(rows.len(), 7);
            let mut sorted = rows.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 7, "duplicate rows for id {id}");
            assert!(rows.iter().all(|&r| r < 1000));
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let s1 = ColumnSampler::new(512, 5, 7);
        let s2 = ColumnSampler::new(512, 5, 7);
        for id in [0u64, 1, u64::MAX, 123456789] {
            assert_eq!(s1.rows(id), s2.rows(id));
        }
    }

    #[test]
    fn seed_changes_columns() {
        let s1 = ColumnSampler::new(512, 5, 1);
        let s2 = ColumnSampler::new(512, 5, 2);
        let differs = (0..100u64).any(|id| s1.rows(id) != s2.rows(id));
        assert!(differs);
    }

    #[test]
    fn m_equals_l_is_all_rows() {
        let s = ColumnSampler::new(5, 5, 3);
        let mut rows = s.rows(99);
        rows.sort_unstable();
        assert_eq!(rows, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rows_roughly_uniform_over_l() {
        let s = ColumnSampler::new(128, 4, 11);
        let mut counts = vec![0u32; 128];
        for id in 0..20_000u64 {
            for &r in &s.rows(id) {
                counts[r as usize] += 1;
            }
        }
        // 80_000 placements over 128 rows: mean 625.
        for (r, &c) in counts.iter().enumerate() {
            assert!((450..800).contains(&c), "row {r} count {c}");
        }
    }
}
