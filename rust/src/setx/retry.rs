//! Client-side recovery: a seeded [`RetryPolicy`] and [`Setx::run_with_retry`].
//!
//! The protocol is multi-round and stateful on the wire (sketch → residue →
//! SMF → confirm), so a dropped or truncated frame mid-ladder kills the whole
//! conversation — and, as with any reconciliation protocol whose residual
//! failure answer is retransmission, the cheap fix is to reconnect and re-run.
//! This module is that loop, shared by every caller that retries:
//!
//! * [`SetxError::is_transient`] is the classification contract: transport
//!   I/O, an admission [`SetxError::ServerBusy`], and a peer close are worth a
//!   fresh connection; config mismatches and protocol faults are not
//!   (retrying against a corrupting or incompatible peer reproduces the
//!   failure).
//! * [`RetryPolicy`] is capped exponential backoff with deterministic,
//!   seeded per-client jitter — the exact schedule the server loadgen has
//!   always used, now one shared implementation
//!   ([`crate::server::loadgen`] is a caller of this policy, not a sibling).
//! * [`Setx::run_with_retry`] reconnects through a caller-supplied transport
//!   factory, honors the server's `retry_after_ms` hint carried by
//!   [`SetxError::ServerBusy`], and accounts the bytes burned by failed
//!   attempts in [`SetxReport::retry_bytes`] — recovery is visible, not free.
//!
//! ```
//! use commonsense::data::synth;
//! use commonsense::setx::transport::{mem_pair, FaultKind, FaultPlan};
//! use commonsense::setx::{RetryPolicy, Setx};
//! use std::sync::Arc;
//!
//! let (a, b) = synth::overlap_pair(400, 8, 8, 3);
//! let policy = RetryPolicy { base_ms: 0, cap_ms: 0, ..RetryPolicy::default() };
//! let alice = Setx::builder(&a).retry_policy(policy).build().unwrap();
//! let bob = Arc::new(Setx::builder(&b).build().unwrap());
//! // Kill the first conversation at its 2nd frame; later attempts run clean
//! // (the injector's counters persist across reconnects).
//! let chaos = FaultPlan::new(1).fail_nth(FaultKind::DropConnection, None, 2).injector();
//! let mut peers = Vec::new();
//! let report = alice
//!     .run_with_retry(7, |_attempt| {
//!         let (client_end, server_end) = mem_pair();
//!         let bob = Arc::clone(&bob);
//!         peers.push(std::thread::spawn(move || {
//!             let mut t = server_end;
//!             let _ = bob.run(&mut t);
//!         }));
//!         Ok(chaos.wrap(client_end))
//!     })
//!     .unwrap();
//! for p in peers {
//!     p.join().unwrap();
//! }
//! assert_eq!(report.retries, 1);
//! assert_eq!(report.attempts_used(), 2);
//! assert!(report.retry_bytes > 0); // the failed attempt's bytes, accounted
//! assert_eq!(report.intersection, synth::intersect(&a, &b));
//! ```

use super::transport::Transport;
use super::{Setx, SetxError, SetxReport};
use crate::hash::split_mix64;

/// Capped exponential backoff with deterministic, seeded jitter. `Copy` and
/// deliberately **not** part of the config fingerprint ([`super::SetxConfig`]
/// carries one): when to reconnect is a local client decision, not protocol
/// state, so peers with different policies interoperate.
///
/// The schedule of the k-th retry (k = 1, 2, …):
///
/// ```text
/// base    = max(server retry_after_ms hint, base_ms)
/// backoff = min(base · 2^min(k−1, 6), cap_ms)
/// jitter  = split_mix64(client_key ⊕ (k << 32) ⊕ jitter_seed) mod (base/2 + 1)
/// wait    = backoff + jitter milliseconds
/// ```
///
/// so a rejected burst neither re-arrives as a burst nor synchronizes across
/// runs, and a given fleet's retry schedule is exactly reproducible from its
/// seed. With `base_ms = 0` (and no server hint) the wait is exactly zero —
/// the chaos tests' no-sleep configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Reconnect attempts after the first failure (0 = never retry; the
    /// default 3 matches the loadgen's historical budget).
    pub max_retries: u32,
    /// Floor of the backoff base in milliseconds; a larger server
    /// `retry_after_ms` hint overrides it per retry.
    pub base_ms: u64,
    /// Ceiling on the exponential part of the wait, milliseconds (jitter may
    /// still ride on top, bounded by `base/2`).
    pub cap_ms: u64,
    /// Seed of the deterministic jitter hash (mixed with the caller's
    /// `client_key` and the attempt number).
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 3, base_ms: 10, cap_ms: 2_000, jitter_seed: 0xC0FFEE }
    }
}

impl RetryPolicy {
    /// The policy that never retries — [`Setx::run_with_retry`] under it is
    /// exactly one [`Setx::run`] plus report bookkeeping.
    pub fn none() -> Self {
        RetryPolicy { max_retries: 0, ..RetryPolicy::default() }
    }

    /// Milliseconds to wait before retry number `attempt` (1-based), given the
    /// server's `retry_after_ms` hint from the rejection (0 = no hint).
    /// Deterministic in `(client_key, attempt, jitter_seed)`.
    pub fn backoff_ms(&self, client_key: u64, attempt: u32, hint_ms: u32) -> u64 {
        let attempt = attempt.max(1);
        let base = u64::from(hint_ms).max(self.base_ms);
        let backoff = base.saturating_mul(1u64 << (attempt - 1).min(6)).min(self.cap_ms);
        let jitter = split_mix64(client_key ^ (u64::from(attempt) << 32) ^ self.jitter_seed)
            % (base / 2 + 1);
        backoff.saturating_add(jitter)
    }
}

impl Setx {
    /// [`Setx::run`], resurrected across transient failures: on an
    /// [`is_transient`](SetxError::is_transient) error the transport is
    /// dropped (its byte counters folded into [`SetxReport::retry_bytes`]),
    /// the policy's backoff elapses, and `connect` is called for a fresh
    /// transport — up to the configured
    /// ([`SetxBuilder::retry_policy`](super::SetxBuilder::retry_policy))
    /// `max_retries` reconnects. Fatal errors (and retry exhaustion) surface
    /// immediately as `Err`.
    ///
    /// `client_key` decorrelates the jitter across a fleet (loadgen passes the
    /// client index); `connect` receives the 0-based attempt number. A
    /// [`SetxError::ServerBusy`] rejection feeds its `retry_after_ms` hint
    /// into the backoff base, so clients respect server pushback.
    pub fn run_with_retry<T, F>(
        &self,
        client_key: u64,
        connect: F,
    ) -> Result<SetxReport, SetxError>
    where
        T: Transport,
        F: FnMut(u32) -> Result<T, SetxError>,
    {
        let policy = self.cfg.retry;
        self.run_with_retry_observed(&policy, client_key, connect, |_, _| {})
    }

    /// [`Setx::run_with_retry`] with an explicit policy and an observer called
    /// once per performed retry with `(error being retried, backoff_ms about
    /// to elapse)` — how the loadgen tells busy-pushback retries from fault
    /// retries without owning the loop.
    pub fn run_with_retry_observed<T, F, O>(
        &self,
        policy: &RetryPolicy,
        client_key: u64,
        mut connect: F,
        mut on_retry: O,
    ) -> Result<SetxReport, SetxError>
    where
        T: Transport,
        F: FnMut(u32) -> Result<T, SetxError>,
        O: FnMut(&SetxError, u64),
    {
        let mut retries = 0u32;
        let mut retry_bytes = 0usize;
        loop {
            let (result, moved) = match connect(retries) {
                Ok(mut transport) => {
                    let result = self.run(&mut transport);
                    (result, transport.bytes_moved())
                }
                Err(e) => (Err(e), None),
            };
            match result {
                Ok(mut report) => {
                    report.retries = retries;
                    report.retry_bytes = retry_bytes;
                    return Ok(report);
                }
                Err(err) => {
                    if !err.is_transient() || retries >= policy.max_retries {
                        return Err(err);
                    }
                    if let Some((sent, received)) = moved {
                        retry_bytes += sent + received;
                    }
                    retries += 1;
                    let hint = match &err {
                        SetxError::ServerBusy { retry_after_ms, .. } => *retry_after_ms,
                        _ => 0,
                    };
                    let backoff = policy.backoff_ms(client_key, retries, hint);
                    on_retry(&err, backoff);
                    if backoff > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(backoff));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::transport::{mem_pair, FaultKind, FaultPlan};
    use super::super::Setx;
    use super::*;
    use crate::data::synth;
    use std::sync::Arc;

    /// Zero-wait policy for fault-path tests: base 0 and no hint make every
    /// computed backoff exactly 0 ms, so nothing sleeps.
    fn instant_policy(max_retries: u32) -> RetryPolicy {
        RetryPolicy { max_retries, base_ms: 0, cap_ms: 0, jitter_seed: 1 }
    }

    #[test]
    fn backoff_matches_the_documented_schedule() {
        let p = RetryPolicy::default();
        // Deterministic in (key, attempt, seed).
        assert_eq!(p.backoff_ms(7, 1, 0), p.backoff_ms(7, 1, 0));
        // The exact loadgen formula, spelled out.
        for (key, attempt, hint) in [(0u64, 1u32, 0u32), (3, 2, 0), (9, 4, 120), (1, 9, 0)] {
            let base = u64::from(hint).max(p.base_ms);
            let backoff = base.saturating_mul(1u64 << (attempt - 1).min(6)).min(p.cap_ms);
            let jitter = split_mix64(key ^ (u64::from(attempt) << 32) ^ p.jitter_seed)
                % (base / 2 + 1);
            assert_eq!(p.backoff_ms(key, attempt, hint), backoff + jitter);
        }
        // The server hint raises the base: never wait less than the hint says.
        assert!(p.backoff_ms(0, 1, 500) >= 500);
        // The exponential part is capped (jitter ≤ base/2 on top).
        let base = 500u64;
        assert!(p.backoff_ms(0, 12, base as u32) <= p.cap_ms + base / 2);
        // Attempt 0 is treated as 1 (callers count retries 1-based).
        assert_eq!(p.backoff_ms(4, 0, 0), p.backoff_ms(4, 1, 0));
        // Zero-wait config used by the chaos tests really waits zero.
        assert_eq!(instant_policy(3).backoff_ms(123, 5, 0), 0);
    }

    /// Run `alice` with retries against fresh in-memory peers, one spawned per
    /// connect, each wrapped by `injector`. Returns (outcome, connects made).
    fn retry_over_mem(
        alice: &Setx,
        bob: &Arc<Setx>,
        injector: &crate::setx::transport::FaultInjector,
        policy: &RetryPolicy,
        retried: &mut Vec<bool>,
    ) -> (Result<crate::setx::SetxReport, crate::setx::SetxError>, u32) {
        let mut connects = 0u32;
        let mut peers = Vec::new();
        let result = alice.run_with_retry_observed(
            policy,
            0,
            |_attempt| {
                connects += 1;
                let (client_end, server_end) = mem_pair();
                let bob = Arc::clone(bob);
                peers.push(std::thread::spawn(move || {
                    let mut t = server_end;
                    let _ = bob.run(&mut t);
                }));
                Ok(injector.wrap(client_end))
            },
            |err, _backoff| retried.push(err.is_transient()),
        );
        for p in peers {
            p.join().unwrap();
        }
        (result, connects)
    }

    #[test]
    fn run_with_retry_converges_after_a_transient_fault() {
        let (a, b) = synth::overlap_pair(600, 12, 15, 5);
        let alice = Setx::builder(&a).build().unwrap();
        let bob = Arc::new(Setx::builder(&b).build().unwrap());
        // The 2nd frame the injector sees (the client's first recv) dies; the
        // shared counters make every later connection clean.
        let injector =
            FaultPlan::new(7).fail_nth(FaultKind::DropConnection, None, 2).injector();
        let mut retried = Vec::new();
        let (result, connects) =
            retry_over_mem(&alice, &bob, &injector, &instant_policy(2), &mut retried);
        let report = result.unwrap();
        assert_eq!(connects, 2);
        assert_eq!(retried, vec![true]);
        assert_eq!(report.retries, 1);
        assert_eq!(report.attempts_used(), 2);
        assert!(report.retry_bytes > 0, "failed attempt's bytes must be accounted");
        assert_eq!(report.intersection, synth::intersect(&a, &b));
        // The successful conversation's own accounting is untouched by the
        // failed attempt: comm holds this conversation only.
        assert!(report.total_bytes() > 0);
        assert_eq!(injector.fired(), 1);
    }

    #[test]
    fn fatal_errors_do_not_retry() {
        let (a, b) = synth::overlap_pair(400, 8, 8, 2);
        let alice = Setx::builder(&a).build().unwrap();
        let bob = Arc::new(Setx::builder(&b).build().unwrap());
        // Corrupt the client's first received frame: MalformedFrame is fatal.
        let injector = FaultPlan::new(11).fail_nth(FaultKind::FlipBytes, None, 2).injector();
        let mut retried = Vec::new();
        let (result, connects) =
            retry_over_mem(&alice, &bob, &injector, &instant_policy(3), &mut retried);
        assert!(matches!(result, Err(crate::setx::SetxError::MalformedFrame(_))));
        assert_eq!(connects, 1, "a fatal error must not burn the retry budget");
        assert!(retried.is_empty());
    }

    #[test]
    fn retry_budget_exhausts_to_the_last_error() {
        let (a, b) = synth::overlap_pair(400, 8, 8, 9);
        let alice = Setx::builder(&a).build().unwrap();
        let bob = Arc::new(Setx::builder(&b).build().unwrap());
        // Every frame dies: no attempt can ever succeed.
        let injector = FaultPlan::new(13)
            .fail_with_probability(FaultKind::DropConnection, None, 1.0)
            .injector();
        let mut retried = Vec::new();
        let (result, connects) =
            retry_over_mem(&alice, &bob, &injector, &instant_policy(2), &mut retried);
        assert!(matches!(result, Err(crate::setx::SetxError::Io(_))));
        assert_eq!(connects, 3, "first attempt + max_retries reconnects");
        assert_eq!(retried, vec![true, true]);
    }
}
