//! Partitioned-parallel driver over the facade endpoints (§7.3's scale-out remark,
//! PBS-style) — the third "transport" of the one front door.
//!
//! Hash-partition the universe with a seed derived from the shared protocol seed; each
//! partition is an independent SetX conversation between two [`Endpoint`]s driven by the
//! same [`drive_endpoints`] pump the in-memory path uses, scheduled on a **bounded worker
//! pool** (at most `threads` OS threads race on an atomic partition counter; a live-worker
//! high-water mark keeps the cap a *tested* invariant).
//!
//! Negotiation happens **once, globally** — a single `EstHello` exchange (charged to the
//! `Handshake` phase of both reports) fixes `d̂` and the per-side split; partitions are
//! then provisioned with Poisson-padded per-partition estimates, exactly how PBS sizes
//! its sub-sketches. The aggregate result is the same pair of [`SetxReport`]s every other
//! path returns, with the per-partition logs merged.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::endpoint::{
    build_est_hello, drive_endpoints, negotiate, union_estimate, Endpoint, Negotiated,
};
use crate::decoder::DecoderCache;
use crate::sketch::EncodeConfig;
use super::{ProtocolKind, Setx, SetxError, SetxReport};
use crate::hash::hash_u64;
use crate::metrics::{CommLog, Stats};
use crate::protocol::session::frame_phase;
use crate::protocol::wire::Msg;

/// Aggregate outcome of a partitioned run: the two endpoint reports plus pool metadata.
#[derive(Clone, Debug)]
pub struct PartitionedReport {
    /// The client endpoint's aggregated report (intersection, uniques, merged comm log).
    pub client: SetxReport,
    /// The server endpoint's aggregated report.
    pub server: SetxReport,
    pub partitions: usize,
    /// High-water mark of concurrently-live partition workers — always ≤ the `threads`
    /// argument (the regression guard for the bounded pool).
    pub peak_workers: usize,
    /// Per-partition total-byte statistics (for the ablation table).
    pub bytes_stats: Stats,
}

/// Partition a set by `hash(id) % parts`. `parts == 0` is clamped to a single partition
/// (degenerate but well-defined: everything lands in partition 0, no `hash % 0` panic).
pub fn partition(ids: &[u64], parts: usize, seed: u64) -> Vec<Vec<u64>> {
    let parts = parts.max(1);
    let mut out = vec![Vec::with_capacity(ids.len() / parts + 1); parts];
    for &id in ids {
        out[(hash_u64(id, seed) % parts as u64) as usize].push(id);
    }
    out
}

/// Run one partitioned conversation between `client` and `server` endpoints (both sets in
/// this process) over `parts` hash partitions on a worker pool of at most `threads` OS
/// threads (both clamped to ≥ 1; `threads` additionally to `parts`).
pub fn run_partitioned(
    client: &Setx,
    server: &Setx,
    parts: usize,
    threads: usize,
) -> Result<PartitionedReport, SetxError> {
    let ours = client.cfg.fingerprint();
    let theirs = server.cfg.fingerprint();
    if ours != theirs {
        return Err(SetxError::ConfigMismatch { ours, theirs });
    }
    let cfg = &client.cfg;
    let parts = parts.max(1);
    let threads = threads.clamp(1, parts);

    // ---- Global negotiation: one EstHello exchange, charged to both transcripts. ----
    let (msg_c, ests_c) = build_est_hello(cfg, &client.set);
    let (msg_s, ests_s) = build_est_hello(cfg, &server.set);
    let Msg::EstHello {
        set_len: s_len,
        explicit_d: s_d,
        strata: s_st,
        minhash: s_mh,
        codec: s_codec,
        ..
    } = &msg_s
    else {
        unreachable!("build_est_hello always builds an EstHello");
    };
    let nego_c = negotiate(
        cfg,
        true,
        client.set.len(),
        ests_c.as_ref(),
        *s_len as usize,
        *s_d,
        s_st.as_deref(),
        s_mh.as_deref(),
        *s_codec,
    )?;
    drop(ests_s);
    let mut comm = CommLog::new();
    comm.record_framed(true, frame_phase(&msg_c), msg_c.wire_len(), msg_c.raw_wire_len());
    comm.record_framed(false, frame_phase(&msg_s), msg_s.wire_len(), msg_s.raw_wire_len());

    // ---- Partitioning + per-partition provisioning (Poisson-padded, as PBS). ----
    let part_seed = cfg.seed ^ 0x9a27_11;
    let c_parts = partition(&client.set, parts, part_seed);
    let s_parts = partition(&server.set, parts, part_seed);
    let pad = |d: usize| -> usize {
        let mu = d as f64 / parts as f64;
        (mu + 3.0 * mu.sqrt() + 4.0).ceil() as usize
    };
    let (dc, ds) = (pad(nego_c.est_local), pad(nego_c.est_peer));
    // Independent matrices per partition: perturb the shared seed.
    let cfgs: Vec<super::SetxConfig> = (0..parts)
        .map(|p| {
            let mut c = *cfg;
            c.seed ^= hash_u64(p as u64, 0x9a27_12);
            // The aggregate report carries no timeline (see `empty_report`), so don't
            // pay for per-partition recording nobody will read.
            c.tracing = false;
            c
        })
        .collect();

    // ---- Bounded pool: workers race on `next` for partition indices. ----
    let next = AtomicUsize::new(0);
    let active = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let results: Vec<Result<(SetxReport, SetxReport), SetxError>> =
        std::thread::scope(|scope| {
            let worker = || {
                let mut local = Vec::new();
                let mut p = next.fetch_add(1, Ordering::Relaxed);
                while p < parts {
                    let live = active.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(live, Ordering::SeqCst);
                    let (cp, sp) = (&c_parts[p], &s_parts[p]);
                    let d_hat = (dc + ds).max(1);
                    let n_union = union_estimate(cp.len(), sp.len(), d_hat).max(64);
                    let nego_cp = Negotiated {
                        d_hat,
                        n_union,
                        est_local: dc,
                        est_peer: ds,
                        ..nego_c
                    };
                    let nego_sp = Negotiated {
                        est_local: ds,
                        est_peer: dc,
                        initiator: !nego_cp.initiator,
                        ..nego_cp
                    };
                    let mut ec = Endpoint::with_negotiated(&cfgs[p], cp, true, nego_cp);
                    let mut es = Endpoint::with_negotiated(&cfgs[p], sp, false, nego_sp);
                    // This pool already saturates the machine with partition workers;
                    // serial decoder builds *and* serial sketch encodes inside each
                    // partition avoid an extra parts × cores fan-out of nested threads.
                    ec.set_cache(DecoderCache::with_build_threads(1));
                    es.set_cache(DecoderCache::with_build_threads(1));
                    ec.set_encode(EncodeConfig::serial());
                    es.set_encode(EncodeConfig::serial());
                    local.push(drive_endpoints(&mut ec, &mut es));
                    active.fetch_sub(1, Ordering::SeqCst);
                    p = next.fetch_add(1, Ordering::Relaxed);
                }
                local
            };
            let handles: Vec<_> = (0..threads).map(|_| scope.spawn(worker)).collect();
            handles.into_iter().flat_map(|h| h.join().expect("partition worker")).collect()
        });

    // ---- Aggregate into the two endpoint reports. ----
    let mut agg_c = empty_report(comm.clone(), true);
    let mut agg_s = empty_report(comm, false);
    let mut bytes_stats = Stats::new();
    for result in results {
        let (rc, rs) = result?;
        bytes_stats.push(rc.total_bytes() as f64);
        merge_into(&mut agg_c, rc);
        merge_into(&mut agg_s, rs);
    }
    finalize(&mut agg_c);
    finalize(&mut agg_s);
    Ok(PartitionedReport {
        client: agg_c,
        server: agg_s,
        partitions: parts,
        peak_workers: peak.into_inner(),
        bytes_stats,
    })
}

fn empty_report(comm: CommLog, local_is_alice: bool) -> SetxReport {
    SetxReport {
        intersection: Vec::new(),
        local_unique: Vec::new(),
        // The escalation floor: stays `Uni` only if *every* partition ran unidirectional.
        kind: ProtocolKind::Uni,
        converged: true,
        attempts: 1,
        rounds: 0,
        retries: 0,
        retry_bytes: 0,
        comm,
        local_is_alice,
        // Partitions run concurrently on the pool: a merged timeline would interleave
        // unrelated conversations, so the aggregate deliberately carries none.
        trace: crate::obs::SessionTrace::default(),
    }
}

/// `Bidi` dominates `Uni`: a partitioned run "was unidirectional" only if every
/// partition's conversation was.
fn escalate(a: ProtocolKind, b: ProtocolKind) -> ProtocolKind {
    if a == ProtocolKind::Bidi || b == ProtocolKind::Bidi {
        ProtocolKind::Bidi
    } else {
        ProtocolKind::Uni
    }
}

fn merge_into(agg: &mut SetxReport, part: SetxReport) {
    agg.intersection.extend(part.intersection);
    agg.local_unique.extend(part.local_unique);
    // Max-escalation, NOT last-partition-wins: one partition falling back to the
    // bidirectional ladder must show in the aggregate even if later-merged partitions
    // stayed unidirectional.
    agg.kind = escalate(agg.kind, part.kind);
    agg.converged &= part.converged;
    agg.attempts = agg.attempts.max(part.attempts);
    // Partitions run concurrently, so the paper-sense round count of the aggregate is
    // the slowest partition's, not the sum (which would inflate linearly with `parts`).
    agg.rounds = agg.rounds.max(part.rounds);
    // Recovery cost is additive across partitions (unlike rounds, every failed
    // attempt's bytes were really spent).
    agg.retries += part.retries;
    agg.retry_bytes += part.retry_bytes;
    agg.comm.extend(&part.comm);
}

fn finalize(agg: &mut SetxReport) {
    agg.intersection.sort_unstable();
    agg.local_unique.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn partition_is_disjoint_cover() {
        let ids: Vec<u64> = (0..10_000u64).collect();
        let parts = partition(&ids, 8, 1);
        assert_eq!(parts.len(), 8);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 10_000);
        // Roughly balanced.
        for p in &parts {
            assert!((1_000..1_550).contains(&p.len()), "part size {}", p.len());
        }
    }

    #[test]
    fn partitioned_facade_is_exact_and_bounded() {
        let (a, b) = synth::overlap_pair(12_000, 120, 150, 3);
        let alice = Setx::builder(&a).build().unwrap();
        let bob = Setx::builder(&b).build().unwrap();
        let out = run_partitioned(&alice, &bob, 8, 4).unwrap();
        assert_eq!(out.client.local_unique, synth::difference(&a, &b));
        assert_eq!(out.server.local_unique, synth::difference(&b, &a));
        assert_eq!(out.client.intersection, synth::intersect(&a, &b));
        assert_eq!(out.client.intersection, out.server.intersection);
        assert_eq!(out.partitions, 8);
        assert!((1..=4).contains(&out.peak_workers), "cap violated: {}", out.peak_workers);
        // Mirror accounting holds for the merged logs too.
        assert_eq!(out.client.bytes_sent(), out.server.bytes_received());
        assert_eq!(out.client.total_bytes(), out.server.total_bytes());
    }

    #[test]
    fn merge_is_max_escalation_and_max_rounds() {
        // Direct regression on the aggregation semantics: `kind` must not be
        // last-partition-wins and `rounds` must not sum across partitions.
        let mk = |kind, rounds, attempts| SetxReport {
            intersection: Vec::new(),
            local_unique: Vec::new(),
            kind,
            converged: true,
            attempts,
            rounds,
            retries: 0,
            retry_bytes: 0,
            comm: CommLog::new(),
            local_is_alice: true,
            trace: crate::obs::SessionTrace::default(),
        };
        let mut agg = empty_report(CommLog::new(), true);
        merge_into(&mut agg, mk(ProtocolKind::Uni, 1, 1));
        assert_eq!(agg.kind, ProtocolKind::Uni);
        merge_into(&mut agg, mk(ProtocolKind::Bidi, 7, 2));
        // A trailing Uni partition must not mask the escalated one.
        merge_into(&mut agg, mk(ProtocolKind::Uni, 1, 1));
        finalize(&mut agg);
        assert_eq!(agg.kind, ProtocolKind::Bidi, "kind regressed to last-partition-wins");
        assert_eq!(agg.rounds, 7, "rounds must be the per-partition max, not a sum");
        assert_eq!(agg.attempts, 2);
    }

    #[test]
    fn mixed_subset_split_escalates_kind_without_inflating_rounds() {
        use crate::setx::DiffSize;
        // A is a subset of B except for ONE element, and the explicit d slightly
        // undercounts, so negotiation sees a zero-unique initiator and every partition
        // opens unidirectionally (Mode::Auto). The partition holding A's unique element
        // cannot decode unidirectionally (Alice-side mass is unreachable for the
        // decoder), fails its attempt, and climbs the ladder to bidirectional — a real
        // mixed Uni/Bidi split.
        let common: Vec<u64> = (0..4000u64).collect();
        let mut a = common.clone();
        a.push(99_999);
        let mut b = common.clone();
        b.extend(10_000u64..10_300);
        // safety 1.5 gives the subset partitions ample sketch headroom, so the ONLY
        // escalation in the run is the structural one (the A-unique partition).
        let alice =
            Setx::builder(&a).diff_size(DiffSize::Explicit(299)).safety(1.5).build().unwrap();
        let bob =
            Setx::builder(&b).diff_size(DiffSize::Explicit(299)).safety(1.5).build().unwrap();
        let out = run_partitioned(&alice, &bob, 4, 2).unwrap();
        // Exactness first: the escalated partition still recovers everything.
        assert_eq!(out.client.local_unique, vec![99_999]);
        assert_eq!(out.server.local_unique, (10_000u64..10_300).collect::<Vec<_>>());
        assert_eq!(out.client.intersection, common);
        // The aggregate must surface the escalation even though most partitions stayed
        // unidirectional (and regardless of merge order).
        assert_eq!(out.client.kind, ProtocolKind::Bidi, "escalated partition was masked");
        assert_eq!(out.server.kind, ProtocolKind::Bidi);
        assert!(out.client.attempts >= 2, "ladder fired in some partition");
        // Rounds are the slowest partition's count: strictly fewer than the total
        // payload frames the merged transcript holds (each subset partition adds its own
        // sketch frame on top).
        let total_payload = out.client.comm.payload_frames();
        assert!(out.client.rounds >= 2, "escalated partition spans attempts");
        assert!(
            out.client.rounds < total_payload,
            "rounds {} inflated toward the merged total {}",
            out.client.rounds,
            total_payload
        );
    }

    #[test]
    fn pure_subset_split_stays_uni_with_single_round() {
        use crate::setx::DiffSize;
        // Exact subset: every partition completes the one-message protocol, so the
        // aggregate is Uni / 1 attempt / 1 round — not `rounds == parts`.
        let a: Vec<u64> = (0..4000u64).collect();
        let mut b = a.clone();
        b.extend(10_000u64..10_300);
        let alice =
            Setx::builder(&a).diff_size(DiffSize::Explicit(300)).safety(1.5).build().unwrap();
        let bob =
            Setx::builder(&b).diff_size(DiffSize::Explicit(300)).safety(1.5).build().unwrap();
        let out = run_partitioned(&alice, &bob, 4, 2).unwrap();
        assert_eq!(out.client.kind, ProtocolKind::Uni);
        assert_eq!(out.client.attempts, 1);
        assert_eq!(out.client.rounds, 1, "rounds must not scale with parts");
        assert!(out.client.local_unique.is_empty());
        assert_eq!(out.server.local_unique, (10_000u64..10_300).collect::<Vec<_>>());
        assert_eq!(out.client.intersection, a);
    }

    #[test]
    fn zero_parts_and_threads_clamp() {
        let (a, b) = synth::overlap_pair(1_000, 20, 20, 8);
        let alice = Setx::builder(&a).build().unwrap();
        let bob = Setx::builder(&b).build().unwrap();
        let out = run_partitioned(&alice, &bob, 0, 0).unwrap();
        assert_eq!(out.partitions, 1);
        assert_eq!(out.peak_workers, 1);
        assert_eq!(out.client.local_unique, synth::difference(&a, &b));
        assert_eq!(out.server.local_unique, synth::difference(&b, &a));
    }
}
