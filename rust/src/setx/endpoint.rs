//! The sans-io endpoint state machine behind [`crate::setx::Setx`].
//!
//! An [`Endpoint`] wraps the protocol engine ([`Session`]) with everything the facade
//! promises on top of it:
//!
//! 1. **Estimator handshake** — both ends open with an `EstHello` frame (config
//!    fingerprint, set cardinality, and — for [`DiffSize::Estimated`] — serialized
//!    Strata + MinHash estimators). From the exchanged data both sides *independently
//!    and identically* compute the difference estimate `d̂`, the per-side unique-count
//!    split, the initiator role (smaller estimated unique count; tie → the transport's
//!    client end), and whether [`Mode::Auto`] starts unidirectional.
//! 2. **Attempts and the escalation ladder** — each attempt ends with a `Confirm`
//!    exchange. On failure the initiator re-opens *on the same connection* with the
//!    sketch length escalated by [`SetxConfig::ladder_factor`]; the ladder bottoming out
//!    is the only way a decode failure reaches the caller, as a typed
//!    [`SetxError::Decode`].
//! 3. **Uniform accounting** — every frame the endpoint itself handles is charged to its
//!    [`CommLog`]; frames handled by an inner [`Session`] are charged by the session and
//!    merged when the attempt ends. Both endpoints of a conversation record identical
//!    totals, whatever the transport.
//!
//! Like [`Session`], the endpoint is pure message-in/[`Step`]-out: `Setx::run` pumps it
//! over a [`crate::setx::transport::Transport`], and [`drive_endpoints`] pumps a pair
//! in-process (deterministically, no threads) — which is also the per-partition primitive
//! of the partitioned-parallel driver.

use std::collections::{HashSet, VecDeque};
use std::sync::Arc;

use super::{DecodeFailure, DiffSize, Mode, ProtocolKind, SetxConfig, SetxError, SetxReport};
use crate::decoder::DecoderCache;
use crate::metrics::CommLog;
use crate::obs::{SpanKind, Tracer};
use crate::protocol::bidi::BidiOptions;
use crate::protocol::estimate::{MinHashEstimator, StrataEstimator};
use crate::protocol::session::{frame_phase, label, Session, SessionError, SessionEvent};
use crate::protocol::uni;
use crate::protocol::wire::{
    Msg, REASON_NOT_CONVERGED, REASON_OK, REASON_RESIDUE_DECODE, REASON_SKETCH_RECOVERY,
};
use crate::protocol::CsParams;
use crate::sketch::{EncodeConfig, Sketch, SketchSource};

/// Handshake estimator shape: 24 strata × 32 cells ≈ 10 KB plus a 256-hash MinHash
/// signature (~2 KB) per direction. Charged to the `Handshake` phase of the report.
pub(crate) const STRATA_LEVELS: usize = 24;
pub(crate) const STRATA_CELLS: usize = 32;
pub(crate) const MINHASH_K: usize = 256;

/// Estimator seeds derive from the shared protocol seed so both ends build compatible
/// structures without extra negotiation.
pub(crate) fn est_seed(seed: u64) -> u64 {
    seed ^ 0x0e57_1a7a_5eed_0001
}

pub(crate) fn mh_seed(seed: u64) -> u64 {
    seed ^ 0x0e57_4a5b_5eed_0002
}

/// `|A∪B| = (|A| + |B| + d) / 2` — the sketch-sizing estimate shared by the global
/// negotiation and the partitioned driver (callers apply their own floors).
pub(crate) fn union_estimate(len_a: usize, len_b: usize, d: usize) -> usize {
    (len_a + len_b + d) / 2
}

/// What the negotiation fixed for the rest of the connection. Both endpoints compute an
/// equivalent (mirrored) value from the same exchanged data.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Negotiated {
    /// Agreed estimate of `|AΔB|` (≥ 1; also ≥ the set-length gap, which is exact).
    pub d_hat: usize,
    /// Agreed estimate of `|A∪B|` (sketch-length sizing).
    pub n_union: usize,
    /// This endpoint's estimated unique count.
    pub est_local: usize,
    /// The peer's estimated unique count.
    pub est_peer: usize,
    /// Whether this endpoint opens every attempt (fixed for the whole connection).
    pub initiator: bool,
    /// Whether attempt 0 runs the unidirectional protocol (Mode::Uni, or Auto with a
    /// zero-unique initiator — the directional Strata subset signal).
    pub uni_first: bool,
    /// Whether the columnar wire codec is on for this connection: both endpoints must
    /// have advertised the `EstHello` codec flags bit. Off, every subsequent frame is
    /// byte-identical to the pre-codec wire format.
    pub codec: bool,
}

/// What the pump should do after feeding one frame in.
pub(crate) enum Step {
    /// Transmit these frames (in order), then keep receiving.
    Send(Vec<Msg>),
    /// Nothing owed; keep receiving.
    Continue,
    /// Transmit these frames, then the endpoint is complete with this report.
    Finish(Vec<Msg>, Box<SetxReport>),
    /// Transmit these frames best-effort (final Confirm), then fail with this error.
    Fatal(Vec<Msg>, SetxError),
}

enum EpPhase {
    /// Waiting for the peer's `EstHello`.
    AwaitEstHello,
    /// Responder/decoder: waiting for the attempt-opening `Hello`.
    AwaitOpen,
    /// Unidirectional decoder: `Hello` seen, waiting for the sketch.
    UniWaitSketch(CsParams),
    /// Unidirectional sender: sketch sent, waiting for the decoder's verdict.
    UniWaitConfirm,
    /// Bidirectional ping-pong in progress.
    Bidi(Session),
    /// Our side of the attempt ended and our `Confirm` is out; waiting for the peer's.
    WaitConfirm { my_ok: bool, my_reason: u8 },
    /// Terminal (report issued or fatal error).
    Finished,
}

fn phase_label(phase: &EpPhase) -> &'static str {
    match phase {
        EpPhase::AwaitEstHello => "await-est-hello",
        EpPhase::AwaitOpen => "await-open",
        EpPhase::UniWaitSketch(_) => "uni-await-sketch",
        EpPhase::UniWaitConfirm => "uni-await-confirm",
        EpPhase::Bidi(_) => "bidi-session",
        EpPhase::WaitConfirm { .. } => "await-confirm",
        EpPhase::Finished => "finished",
    }
}

pub(crate) fn failure_to_reason(f: DecodeFailure) -> u8 {
    match f {
        DecodeFailure::SketchRecovery => REASON_SKETCH_RECOVERY,
        DecodeFailure::ResidueDecode => REASON_RESIDUE_DECODE,
        DecodeFailure::NotConverged => REASON_NOT_CONVERGED,
    }
}

pub(crate) fn reason_to_failure(r: u8) -> DecodeFailure {
    match r {
        REASON_SKETCH_RECOVERY => DecodeFailure::SketchRecovery,
        REASON_RESIDUE_DECODE => DecodeFailure::ResidueDecode,
        _ => DecodeFailure::NotConverged,
    }
}

/// Build this endpoint's opening `EstHello` (and, for `Estimated`, the estimators it must
/// keep until the peer's frame arrives).
pub(crate) fn build_est_hello(
    cfg: &SetxConfig,
    set: &[u64],
) -> (Msg, Option<(StrataEstimator, MinHashEstimator)>) {
    match cfg.diff {
        DiffSize::Explicit(d) => (
            Msg::EstHello {
                config_fingerprint: cfg.fingerprint(),
                set_len: set.len() as u64,
                explicit_d: Some(d as u64),
                strata: None,
                minhash: None,
                namespace: cfg.namespace(),
                party: None,
                codec: cfg.engine.codec,
            },
            None,
        ),
        DiffSize::Estimated => {
            let mut strata =
                StrataEstimator::with_shape(STRATA_LEVELS, STRATA_CELLS, est_seed(cfg.seed));
            strata.insert_all(set);
            let minhash = MinHashEstimator::build(set, MINHASH_K, mh_seed(cfg.seed));
            // The strata payload rides in the same frame as the codec bit, so its
            // layout follows *our* advertisement (the receiver dispatches on the bit);
            // a codec-off peer still negotiates the connection down for everything
            // after the hello. MinHash bytes are identical in both modes.
            let strata_bytes = if cfg.engine.codec {
                strata.to_columnar_bytes()
            } else {
                strata.to_bytes()
            };
            let msg = Msg::EstHello {
                config_fingerprint: cfg.fingerprint(),
                set_len: set.len() as u64,
                explicit_d: None,
                strata: Some(strata_bytes),
                minhash: Some(minhash.to_bytes()),
                namespace: cfg.namespace(),
                party: None,
                codec: cfg.engine.codec,
            };
            (msg, Some((strata, minhash)))
        }
    }
}

/// Derive the connection-wide negotiation from the peer's `EstHello` payload. Symmetric
/// by construction: all quantities are computed in canonical client/server order, so both
/// endpoints reach mirrored [`Negotiated`] values (and exactly one claims `initiator`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn negotiate(
    cfg: &SetxConfig,
    client: bool,
    my_len: usize,
    my_ests: Option<&(StrataEstimator, MinHashEstimator)>,
    peer_len: usize,
    peer_explicit_d: Option<u64>,
    peer_strata: Option<&[u8]>,
    peer_minhash: Option<&[u8]>,
    peer_codec: bool,
) -> Result<Negotiated, SetxError> {
    let (client_len, server_len) = if client { (my_len, peer_len) } else { (peer_len, my_len) };
    let len_gap = my_len.abs_diff(peer_len);
    let (d_est, dir): (usize, Option<(usize, usize)>) = match cfg.diff {
        DiffSize::Explicit(d) => {
            // The fingerprint already pins the value; this guards frame/config skew.
            match peer_explicit_d {
                Some(pd) if pd as usize == d => {}
                _ => return Err(SetxError::MalformedFrame("explicit-d mismatch in EstHello")),
            }
            (d, None)
        }
        DiffSize::Estimated => {
            let (my_st, my_mh) =
                my_ests.ok_or(SetxError::MalformedFrame("local estimators missing"))?;
            let sb = peer_strata.ok_or(SetxError::MalformedFrame("missing strata estimator"))?;
            let mb = peer_minhash.ok_or(SetxError::MalformedFrame("missing minhash estimator"))?;
            // The peer's strata layout follows its own codec advertisement (the bit
            // travels in the same frame as the payload).
            let peer_st = if peer_codec {
                StrataEstimator::from_columnar_bytes(sb, est_seed(cfg.seed))
            } else {
                StrataEstimator::from_bytes(sb, est_seed(cfg.seed))
            }
            .ok_or(SetxError::MalformedFrame("strata estimator"))?;
            let peer_mh = MinHashEstimator::from_bytes(mb)
                .ok_or(SetxError::MalformedFrame("minhash estimator"))?;
            if !my_st.shape_matches(&peer_st) {
                return Err(SetxError::MalformedFrame("strata shape mismatch"));
            }
            let d_strata = my_st.estimate(&peer_st);
            let (mine_only, theirs_only) = my_st.estimate_directional(&peer_st);
            // Strata is the workhorse (constant-factor error across the range); MinHash
            // takes over where the per-stratum IBLTs saturate — a large difference shows
            // up as a low Jaccard estimate.
            let jaccard = my_mh.jaccard(&peer_mh);
            let d = if jaccard <= 0.9 {
                d_strata.max(my_mh.estimate_d(&peer_mh))
            } else {
                d_strata
            };
            let dir = if client { (mine_only, theirs_only) } else { (theirs_only, mine_only) };
            // Provisioning margin on the *estimate*: overshooting costs O(d log) bytes,
            // undershooting costs a whole ladder rung.
            (d + d / 4, Some(dir))
        }
    };
    // The set-length gap is a hard lower bound on d — and it is exact information.
    let d_hat = d_est.max(len_gap).max(1);
    let (est_client, est_server) = match dir {
        Some((c, s)) if c + s > 0 => {
            // Split d̂ by the directional Strata ratio.
            let ec = ((d_hat as f64 * c as f64) / (c + s) as f64).round() as usize;
            let ec = ec.min(d_hat);
            (ec, d_hat - ec)
        }
        _ => {
            // Split by set lengths: u_client − u_server = |C| − |S| exactly.
            let ec = ((d_hat as i64 + client_len as i64 - server_len as i64) / 2)
                .clamp(0, d_hat as i64) as usize;
            (ec, d_hat - ec)
        }
    };
    // §5.1: the side with the smaller estimated unique count initiates; the transport's
    // client end breaks ties (both sides compute this identically).
    let initiator_is_client = est_client <= est_server;
    let est_initiator = if initiator_is_client { est_client } else { est_server };
    let uni_first = match cfg.mode {
        Mode::Uni => true,
        Mode::Bidi => false,
        Mode::Auto => est_initiator == 0,
    };
    let n_union = union_estimate(client_len, server_len, d_hat).max(2);
    let (est_local, est_peer) =
        if client { (est_client, est_server) } else { (est_server, est_client) };
    Ok(Negotiated {
        d_hat,
        n_union,
        est_local,
        est_peer,
        initiator: client == initiator_is_client,
        uni_first,
        // Both ends must advertise the codec bit; either side off turns it off for the
        // whole connection (the negotiate-down path for mixed deployments).
        codec: cfg.engine.codec && peer_codec,
    })
}

/// Which protocol family attempt `attempt` runs — deterministic from shared data, so both
/// endpoints always agree. `Mode::Auto` tries unidirectional once when the subset signal
/// fired, then falls back to the general bidirectional machinery on any retry.
pub(crate) fn attempt_kind(cfg: &SetxConfig, nego: &Negotiated, attempt: u32) -> ProtocolKind {
    match cfg.mode {
        Mode::Uni => ProtocolKind::Uni,
        Mode::Bidi => ProtocolKind::Bidi,
        Mode::Auto => {
            if attempt == 0 && nego.uni_first {
                ProtocolKind::Uni
            } else {
                ProtocolKind::Bidi
            }
        }
    }
}

/// The endpoint's view of its local set: borrowed for the classic `Setx::run` path
/// (the endpoint lives inside one call frame), or an owned `Arc` snapshot for drivers
/// whose endpoints outlive any caller frame — the readiness-based server parks its
/// per-connection endpoints in a poll-loop table, so they must be `'static`.
pub(crate) enum SetRef<'a> {
    Borrowed(&'a [u64]),
    Owned(Arc<Vec<u64>>),
}

impl SetRef<'_> {
    fn as_slice(&self) -> &[u64] {
        match self {
            SetRef::Borrowed(s) => s,
            SetRef::Owned(v) => v,
        }
    }
}

/// One facade endpoint (see the module docs).
pub(crate) struct Endpoint<'a> {
    /// Owned copy of the declarative config (`SetxConfig` is `Copy`); owning it — rather
    /// than borrowing the caller's — is what lets [`Endpoint::new_owned`] hand out
    /// `'static` endpoints for the server's connection table.
    cfg: SetxConfig,
    set: SetRef<'a>,
    /// Client end of the transport; doubles as the "Alice" direction label and the
    /// initiator tie-break.
    client: bool,
    phase: EpPhase,
    comm: CommLog,
    /// 0-based index of the current attempt.
    attempt: u32,
    nego: Option<Negotiated>,
    ests: Option<(StrataEstimator, MinHashEstimator)>,
    unique: Vec<u64>,
    settled: bool,
    kind: ProtocolKind,
    /// Decoder-reuse slot: moved into each session (which checks it out when building its
    /// decoder) and reclaimed when the attempt ends, so ladder attempts and — via
    /// [`Endpoint::take_cache`] — repeat conversations that keep the same matrix skip the
    /// dominant CSR rebuild.
    cache: DecoderCache,
    /// Encode-side parallelism for this endpoint's own-set sketch encodes (from
    /// [`SetxConfig::encode_threads`]; overridable via [`Endpoint::set_encode`]).
    enc: EncodeConfig,
    /// Optional shared host-sketch store (the encode-side sibling of the decoder pool):
    /// consulted per attempt for this endpoint's own-set sketch, so repeat sessions on a
    /// warmed geometry skip the O(m·n) self-encode entirely.
    sketch_source: Option<Arc<dyn SketchSource>>,
    /// Responder-side deferral: the attempt geometry noted at `Hello`, consumed when the
    /// initiator's `Sketch` frame actually arrives. The store checkout (and any encode
    /// it implies) must not happen on a bare `Hello` — a peer could otherwise trigger
    /// O(m·n) encodes and store insertions for attempt geometries it never follows
    /// through on.
    pending_host_matrix: Option<crate::matrix::CsMatrix>,
    /// Timeline recorder (see [`crate::obs`]): `Handshake`/`Estimate` spans around the
    /// `EstHello` exchange, one `Attempt(i)` span per ladder rung, and the per-frame
    /// `Round`/`Confirm` markers — with each inner [`Session`]'s trace (recorded through
    /// a [`Tracer::child`] on the same clock) merged in by [`Endpoint::absorb_session`].
    /// Disabled (zero recording) when [`SetxConfig`]'s `tracing` knob is off.
    tracer: Tracer,
}

impl<'a> Endpoint<'a> {
    pub(crate) fn new(cfg: &SetxConfig, set: &'a [u64], client: bool) -> Endpoint<'a> {
        Self::with_set_ref(*cfg, SetRef::Borrowed(set), client)
    }

    /// An endpoint that *owns* its config and set snapshot, so it has no borrow of the
    /// caller's frame: the server's poll-loop connection table parks these across poll
    /// iterations. The `Arc` keeps `replace_set` cheap — every live session holds its
    /// own consistent snapshot while the server moves on.
    pub(crate) fn new_owned(
        cfg: SetxConfig,
        set: Arc<Vec<u64>>,
        client: bool,
    ) -> Endpoint<'static> {
        Endpoint::with_set_ref(cfg, SetRef::Owned(set), client)
    }

    fn with_set_ref(cfg: SetxConfig, set: SetRef<'a>, client: bool) -> Endpoint<'a> {
        let tracer = if cfg.tracing { Tracer::new() } else { Tracer::disabled() };
        Endpoint {
            cfg,
            set,
            client,
            phase: EpPhase::AwaitEstHello,
            comm: CommLog::new(),
            attempt: 0,
            nego: None,
            ests: None,
            unique: Vec::new(),
            settled: false,
            kind: ProtocolKind::Bidi,
            cache: DecoderCache::new(),
            enc: EncodeConfig { threads: cfg.encode_threads },
            sketch_source: None,
            pending_host_matrix: None,
            tracer,
        }
    }

    /// Override the encode-side parallelism (drivers already running many endpoints in
    /// parallel pin [`EncodeConfig::serial`], as they do for decoder construction).
    pub(crate) fn set_encode(&mut self, enc: EncodeConfig) {
        self.enc = enc;
    }

    /// Attach a shared host-sketch store: every attempt's own-set sketch is checked out
    /// of (or encoded into) it instead of re-encoded per session — the
    /// [`crate::server`] reuse path.
    pub(crate) fn set_sketch_source(&mut self, source: Arc<dyn SketchSource>) {
        self.sketch_source = Some(source);
    }

    /// This endpoint's own-set sketch for the attempt matrix of `params` — from the
    /// shared store when one is attached (O(1) once warmed), else `None` (the caller
    /// encodes inline).
    fn own_sketch(&self, params: &CsParams) -> Option<Arc<Sketch>> {
        self.sketch_source
            .as_ref()
            .map(|src| src.host_sketch(&params.matrix(), self.set.as_slice(), self.enc))
    }

    /// Seed the decoder-reuse cache (typically with the slot a previous conversation of
    /// the same [`super::Setx`] endpoint left behind).
    pub(crate) fn set_cache(&mut self, cache: DecoderCache) {
        self.cache = cache;
    }

    /// Reclaim the decoder-reuse cache for the next conversation. Best-effort: a
    /// conversation torn down mid-session leaves its decoder in the dropped session.
    pub(crate) fn take_cache(&mut self) -> DecoderCache {
        std::mem::take(&mut self.cache)
    }

    /// An endpoint with the negotiation pre-computed (the partitioned driver negotiates
    /// once globally, then provisions every partition) — `start` skips the `EstHello`
    /// exchange and opens the first attempt directly.
    pub(crate) fn with_negotiated(
        cfg: &SetxConfig,
        set: &'a [u64],
        client: bool,
        nego: Negotiated,
    ) -> Endpoint<'a> {
        let mut ep = Endpoint::new(cfg, set, client);
        ep.nego = Some(nego);
        ep
    }

    /// Owned-set variant of [`Endpoint::with_negotiated`]: the multi-party coordinator
    /// negotiates per spoke during its collect phase, then parks one inner endpoint per
    /// out-of-sync spoke in its own state (and the server parks them across poll
    /// iterations), so the endpoint must not borrow the caller's frame.
    pub(crate) fn new_owned_negotiated(
        cfg: SetxConfig,
        set: Arc<Vec<u64>>,
        client: bool,
        nego: Negotiated,
    ) -> Endpoint<'static> {
        let mut ep = Endpoint::new_owned(cfg, set, client);
        ep.nego = Some(nego);
        ep
    }

    /// Opening frames the transport must deliver before the first `on_msg`.
    pub(crate) fn start(&mut self) -> Vec<Msg> {
        if let Some(nego) = self.nego {
            // Pre-negotiated: no estimator handshake.
            if nego.initiator {
                return self.open_attempt();
            }
            self.phase = EpPhase::AwaitOpen;
            return Vec::new();
        }
        // Handshake spans the whole EstHello exchange (closed once `negotiate`
        // succeeds); the nested Estimate spans isolate the estimator build here and the
        // d̂ derivation in `on_msg`.
        self.tracer.open(SpanKind::Handshake);
        self.tracer.open(SpanKind::Estimate);
        let (msg, ests) = build_est_hello(&self.cfg, self.set.as_slice());
        self.tracer.close(SpanKind::Estimate);
        self.ests = ests;
        self.record_sent(&msg);
        self.phase = EpPhase::AwaitEstHello;
        vec![msg]
    }

    pub(crate) fn phase_name(&self) -> &'static str {
        phase_label(&self.phase)
    }

    /// Absorb one incoming frame and report what the transport should do next.
    pub(crate) fn on_msg(&mut self, msg: &Msg) -> Step {
        match (std::mem::replace(&mut self.phase, EpPhase::Finished), msg) {
            (
                EpPhase::AwaitEstHello,
                Msg::EstHello {
                    config_fingerprint,
                    set_len,
                    explicit_d,
                    strata,
                    minhash,
                    namespace,
                    party,
                    codec,
                },
            ) => {
                self.record_recv(msg);
                let ours = self.cfg.fingerprint();
                if *config_fingerprint != ours {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::ConfigMismatch { ours, theirs: *config_fingerprint },
                    );
                }
                // A multi-party join frame aimed at a plain two-party endpoint is a
                // topology mismatch, not something to silently downgrade.
                if party.is_some() {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("multi-party est-hello at two-party endpoint"),
                    );
                }
                // The namespace routes the connection to a tenant; both ends must agree.
                // (The multi-tenant server never reaches this check — it reads the frame
                // *before* constructing the endpoint, with the tenant's own config.)
                if *namespace != self.cfg.namespace() {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("est-hello namespace mismatch"),
                    );
                }
                let Ok(peer_len) = usize::try_from(*set_len) else {
                    return Step::Fatal(Vec::new(), SetxError::MalformedFrame("set_len"));
                };
                let my_ests = self.ests.take();
                self.tracer.open(SpanKind::Estimate);
                let nego_res = negotiate(
                    &self.cfg,
                    self.client,
                    self.set.as_slice().len(),
                    my_ests.as_ref(),
                    peer_len,
                    *explicit_d,
                    strata.as_deref(),
                    minhash.as_deref(),
                    *codec,
                );
                self.tracer.close(SpanKind::Estimate);
                let nego = match nego_res {
                    Ok(n) => n,
                    Err(e) => return Step::Fatal(Vec::new(), e),
                };
                self.tracer.close(SpanKind::Handshake);
                self.nego = Some(nego);
                if nego.initiator {
                    Step::Send(self.open_attempt())
                } else {
                    self.phase = EpPhase::AwaitOpen;
                    Step::Continue
                }
            }
            // Role-gated on the *client* end: only the dialing side can legitimately be
            // turned away at admission. A Busy arriving at a serving endpoint falls
            // through to the catch-all below as an UnexpectedMessage protocol fault —
            // otherwise a malicious client could plant a nonsensical "server busy"
            // diagnosis in the server's own failure log.
            (EpPhase::AwaitEstHello, Msg::Busy { retry_after_ms, namespace }) if self.client => {
                // Admission-control rejection from a multi-client server: the connection
                // carries no session, so surface the typed error (not a protocol fault —
                // the caller may back off and retry). The echoed namespace tells the
                // caller *which* tenant's quota turned it away (0 = the global cap).
                self.record_recv(msg);
                Step::Fatal(
                    Vec::new(),
                    SetxError::ServerBusy {
                        retry_after_ms: *retry_after_ms,
                        namespace: *namespace,
                    },
                )
            }
            (EpPhase::AwaitOpen, m @ Msg::Hello { .. }) => self.on_open_hello(m),
            (EpPhase::UniWaitSketch(params), m @ Msg::Sketch { .. }) => self.uni_decode(&params, m),
            (EpPhase::UniWaitConfirm, Msg::Confirm { ok, reason, attempt }) => {
                self.record_recv(msg);
                if *attempt != self.attempt {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("confirm attempt index"),
                    );
                }
                if *ok {
                    // The decoder verified its recovery; our set is the intersection.
                    self.settled = true;
                    self.finish(Vec::new())
                } else {
                    self.next_attempt(Vec::new(), reason_to_failure(*reason))
                }
            }
            (
                EpPhase::Bidi(mut session),
                m @ (Msg::Hello { .. } | Msg::Sketch { .. } | Msg::Round { .. }),
            ) => {
                if matches!(m, Msg::Sketch { .. }) {
                    // The initiator followed through with its sketch: now (and only
                    // now) check our own-set sketch out of the shared store for the
                    // geometry its Hello announced, so the session skips the O(m·n)
                    // self-encode.
                    if let (Some(src), Some(matrix)) =
                        (&self.sketch_source, self.pending_host_matrix.take())
                    {
                        session.set_host_sketch(src.host_sketch(
                            &matrix,
                            self.set.as_slice(),
                            self.enc,
                        ));
                    }
                }
                match session.on_msg(m) {
                    Ok(SessionEvent::Reply(reply)) => {
                        self.phase = EpPhase::Bidi(session);
                        Step::Send(vec![reply])
                    }
                    Ok(SessionEvent::Continue) => {
                        self.phase = EpPhase::Bidi(session);
                        Step::Continue
                    }
                    Ok(SessionEvent::Done(_)) => {
                        // Session over (settled, or round budget exhausted): issue our
                        // verdict.
                        self.absorb_session(session);
                        let ok = self.settled;
                        let reason = if ok { REASON_OK } else { REASON_NOT_CONVERGED };
                        self.send_confirm_and_wait(ok, reason)
                    }
                    Err(SessionError::SketchRecovery) => {
                        // Recoverable attempt failure (undersized/corrupt sketch):
                        // report it and let the ladder escalate instead of tearing the
                        // connection down.
                        self.absorb_session(session);
                        self.settled = false;
                        self.send_confirm_and_wait(false, REASON_SKETCH_RECOVERY)
                    }
                    Err(e) => {
                        self.absorb_session(session);
                        Step::Fatal(Vec::new(), SetxError::Protocol(e))
                    }
                }
            }
            (EpPhase::Bidi(session), Msg::Confirm { ok, reason, attempt }) => {
                // The peer's side of the attempt ended first (it settled on our `done`
                // flag, or it failed); settle ours from the current session state.
                self.record_recv(msg);
                if *attempt != self.attempt {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("confirm attempt index"),
                    );
                }
                self.absorb_session(session);
                let my_ok = self.settled;
                let my_reason = if my_ok { REASON_OK } else { REASON_NOT_CONVERGED };
                let confirm = Msg::Confirm { ok: my_ok, reason: my_reason, attempt: self.attempt };
                self.record_sent(&confirm);
                self.evaluate(vec![confirm], my_ok, my_reason, *ok, *reason)
            }
            (EpPhase::WaitConfirm { my_ok, my_reason }, Msg::Confirm { ok, reason, attempt }) => {
                self.record_recv(msg);
                if *attempt != self.attempt {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("confirm attempt index"),
                    );
                }
                self.evaluate(Vec::new(), my_ok, my_reason, *ok, *reason)
            }
            (ph @ EpPhase::WaitConfirm { .. }, Msg::Round { .. }) => {
                // A ping-pong frame the peer emitted before it saw our Confirm: charge it
                // and drain it.
                self.record_recv(msg);
                self.phase = ph;
                Step::Continue
            }
            (phase, m) => {
                self.record_recv(m);
                Step::Fatal(
                    Vec::new(),
                    SetxError::Protocol(SessionError::UnexpectedMessage {
                        phase: phase_label(&phase),
                        got: label(m),
                    }),
                )
            }
        }
    }

    /// The responder's dispatch of an attempt-opening `Hello`.
    fn on_open_hello(&mut self, msg: &Msg) -> Step {
        let nego = self.nego.expect("negotiated before AwaitOpen");
        let kind = attempt_kind(&self.cfg, &nego, self.attempt);
        self.kind = kind;
        // One span per ladder rung on this side too: opened when the peer's Hello
        // arrives, closed by `next_attempt`/`finish`.
        self.tracer.open(SpanKind::Attempt(self.attempt));
        match kind {
            ProtocolKind::Bidi => {
                let cache = self.take_cache();
                let engine = BidiOptions { codec: nego.codec, ..self.cfg.engine };
                let mut session =
                    Session::responder_cached(self.set.as_slice(), engine, self.client, cache);
                session.set_encode_config(self.enc);
                session.set_tracer(self.tracer.child());
                // Note the attempt geometry (the `Hello` carries it) but *defer* the
                // store checkout to the initiator's `Sketch` frame — the self-encode is
                // only needed then, and resolving on a bare `Hello` would hand a peer
                // free O(m·n) encodes. Only geometry a ColumnSampler would accept is
                // noted; invalid frames take the session's own typed-error path.
                if let Msg::Hello { l, m, seed, .. } = msg {
                    if self.sketch_source.is_some()
                        && crate::protocol::wire_geometry_ok(*l, *m, *seed)
                    {
                        self.pending_host_matrix =
                            Some(crate::matrix::CsMatrix::new(*l, *m, *seed));
                    }
                }
                match session.on_msg(msg) {
                    Ok(SessionEvent::Continue) => {
                        self.phase = EpPhase::Bidi(session);
                        Step::Continue
                    }
                    Ok(_) => Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("unexpected session event on hello"),
                    ),
                    Err(e) => Step::Fatal(Vec::new(), SetxError::Protocol(e)),
                }
            }
            ProtocolKind::Uni => {
                self.record_recv(msg);
                let Msg::Hello {
                    l,
                    m,
                    seed,
                    universe_bits,
                    est_initiator_unique,
                    est_responder_unique,
                    namespace,
                    ..
                } = msg
                else {
                    return Step::Fatal(Vec::new(), SetxError::MalformedFrame("expected hello"));
                };
                // Mirror the bidi session's namespace check (the uni `Hello` is handled
                // here, outside any `Session`).
                if *namespace != self.cfg.namespace() {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("hello namespace mismatch"),
                    );
                }
                // Adversarial `Hello` hardening: the shared trust-boundary check (same
                // one the session engine applies) — allocation cap plus the m ≤ MAX_M
                // stack-buffer invariant.
                if !crate::protocol::wire_geometry_ok(*l, *m, *seed) {
                    return Step::Fatal(Vec::new(), SetxError::MalformedFrame("hello geometry"));
                }
                let (Ok(ea), Ok(eb)) = (
                    usize::try_from(*est_initiator_unique),
                    usize::try_from(*est_responder_unique),
                ) else {
                    return Step::Fatal(Vec::new(), SetxError::MalformedFrame("hello estimates"));
                };
                let params = CsParams {
                    l: *l,
                    m: *m,
                    seed: *seed,
                    universe_bits: *universe_bits,
                    est_a_unique: ea,
                    est_b_unique: eb,
                };
                self.phase = EpPhase::UniWaitSketch(params);
                Step::Continue
            }
        }
    }

    /// The unidirectional decoder's half of an attempt.
    fn uni_decode(&mut self, params: &CsParams, msg: &Msg) -> Step {
        self.record_recv(msg);
        let host = self.own_sketch(params);
        let enc = self.enc;
        match uni::bob_decode_with(
            msg,
            self.set.as_slice(),
            params,
            &mut self.cache,
            host.as_deref(),
            enc,
        ) {
            Ok((unique, _used_fallback)) => {
                self.unique = unique;
                self.settled = true;
                let confirm = Msg::Confirm { ok: true, reason: REASON_OK, attempt: self.attempt };
                self.record_sent(&confirm);
                self.finish(vec![confirm])
            }
            Err(uni::UniError::Decode(failure)) => {
                let confirm = Msg::Confirm {
                    ok: false,
                    reason: failure_to_reason(failure),
                    attempt: self.attempt,
                };
                self.record_sent(&confirm);
                self.next_attempt(vec![confirm], failure)
            }
            Err(e @ uni::UniError::Frame(_)) => Step::Fatal(Vec::new(), e.into()),
        }
    }

    /// Open attempt `self.attempt` (initiator only): `Hello` (+ sketch) per the attempt's
    /// protocol kind, with the sketch length escalated along the ladder.
    fn open_attempt(&mut self) -> Vec<Msg> {
        let nego = self.nego.expect("negotiated before open_attempt");
        let kind = attempt_kind(&self.cfg, &nego, self.attempt);
        self.kind = kind;
        self.tracer.open(SpanKind::Attempt(self.attempt));
        let params = self.attempt_params(&nego, kind);
        match kind {
            ProtocolKind::Uni => {
                let hello = Msg::Hello {
                    l: params.l,
                    m: params.m,
                    seed: params.seed,
                    universe_bits: params.universe_bits,
                    est_initiator_unique: params.est_a_unique as u64,
                    est_responder_unique: params.est_b_unique as u64,
                    set_len: self.set.as_slice().len() as u64,
                    namespace: self.cfg.namespace(),
                };
                self.tracer.open(SpanKind::SketchEncode);
                let host = self.own_sketch(&params);
                let (sketch, _) = uni::alice_encode_with(
                    self.set.as_slice(),
                    &params,
                    self.enc,
                    host.as_deref(),
                    nego.codec,
                );
                self.tracer.close(SpanKind::SketchEncode);
                self.record_sent(&hello);
                self.record_sent(&sketch);
                self.phase = EpPhase::UniWaitConfirm;
                vec![hello, sketch]
            }
            ProtocolKind::Bidi => {
                // The session records its own frames; they merge into our log at the end
                // of the attempt (absorb_session) — together with the decoder cache it
                // checks out here and refills there.
                let cache = self.take_cache();
                let host = self.own_sketch(&params);
                let engine = BidiOptions { codec: nego.codec, ..self.cfg.engine };
                let (session, opening) = Session::initiator_traced(
                    &params,
                    self.set.as_slice(),
                    engine,
                    self.client,
                    cache,
                    self.enc,
                    host.as_deref(),
                    self.tracer.child(),
                );
                self.phase = EpPhase::Bidi(session);
                opening
            }
        }
    }

    /// CS parameters for the current attempt: calibrated tuning × the config safety ×
    /// the ladder factor, with the shared seed perturbed per attempt so a retry also
    /// redraws the matrix.
    fn attempt_params(&self, nego: &Negotiated, kind: ProtocolKind) -> CsParams {
        let extra = self.cfg.safety * SetxConfig::ladder_factor(self.attempt);
        let mut params = match kind {
            ProtocolKind::Uni => {
                // All difference mass sits on the decoder side under the subset shape.
                let d = nego.est_peer.max(1);
                let mut p = CsParams::tuned_uni_with_safety(nego.n_union, d, extra);
                p.est_a_unique = nego.est_local;
                p.est_b_unique = d;
                p
            }
            ProtocolKind::Bidi => {
                let (ea, eb) = if self.client {
                    (nego.est_local, nego.est_peer)
                } else {
                    (nego.est_peer, nego.est_local)
                };
                CsParams::tuned_bidi_with_safety(nego.n_union, ea, eb, extra)
            }
        };
        params.seed = self
            .cfg
            .seed
            .wrapping_add((self.attempt as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        params.universe_bits = self.cfg.universe_bits;
        params
    }

    /// End our side of a bidirectional attempt: emit the verdict and await the peer's.
    fn send_confirm_and_wait(&mut self, ok: bool, reason: u8) -> Step {
        let confirm = Msg::Confirm { ok, reason, attempt: self.attempt };
        self.record_sent(&confirm);
        self.phase = EpPhase::WaitConfirm { my_ok: ok, my_reason: reason };
        Step::Send(vec![confirm])
    }

    /// Both verdicts are in: finish on double-success, otherwise climb the ladder.
    fn evaluate(
        &mut self,
        out: Vec<Msg>,
        my_ok: bool,
        my_reason: u8,
        peer_ok: bool,
        peer_reason: u8,
    ) -> Step {
        if my_ok && peer_ok {
            return self.finish(out);
        }
        // Keep the most *specific* diagnosis so both endpoints surface the same typed
        // failure: a concrete layer fault (sketch recovery / residue decode) beats the
        // generic non-convergence verdict the surviving side reports.
        let failure = match (my_ok, peer_ok) {
            (false, true) => reason_to_failure(my_reason),
            (true, false) => reason_to_failure(peer_reason),
            _ => {
                let mine = reason_to_failure(my_reason);
                if mine == DecodeFailure::NotConverged {
                    reason_to_failure(peer_reason)
                } else {
                    mine
                }
            }
        };
        self.next_attempt(out, failure)
    }

    /// Advance the ladder: either re-open (initiator), re-arm for the peer's `Hello`
    /// (responder), or — when the ladder is exhausted — fail with the typed error.
    fn next_attempt(&mut self, mut out: Vec<Msg>, failure: DecodeFailure) -> Step {
        self.tracer.close(SpanKind::Attempt(self.attempt));
        self.attempt += 1;
        self.unique.clear();
        self.settled = false;
        if self.attempt >= self.cfg.max_attempts {
            self.phase = EpPhase::Finished;
            return Step::Fatal(out, SetxError::Decode { failure, attempts: self.attempt });
        }
        if self.nego.expect("negotiated").initiator {
            out.extend(self.open_attempt());
            Step::Send(out)
        } else {
            self.phase = EpPhase::AwaitOpen;
            if out.is_empty() {
                Step::Continue
            } else {
                Step::Send(out)
            }
        }
    }

    fn finish(&mut self, out: Vec<Msg>) -> Step {
        self.tracer.close(SpanKind::Attempt(self.attempt));
        self.phase = EpPhase::Finished;
        Step::Finish(out, Box::new(self.report()))
    }

    /// Merge a finished (or abandoned) session's transcript and result into the
    /// endpoint, reclaiming the decoder-reuse cache (now holding the session's decoder).
    fn absorb_session(&mut self, session: Session) {
        let (comm, outcome, cache, trace) = session.into_parts();
        self.comm.extend(&comm);
        self.tracer.absorb(&trace);
        self.unique = outcome.unique;
        self.settled = outcome.converged;
        self.cache = cache;
    }

    fn report(&self) -> SetxReport {
        let mut local_unique = self.unique.clone();
        local_unique.sort_unstable();
        let exclude: HashSet<u64> = local_unique.iter().copied().collect();
        let mut intersection: Vec<u64> =
            self.set.as_slice().iter().copied().filter(|x| !exclude.contains(x)).collect();
        intersection.sort_unstable();
        let rounds = self.comm.payload_frames();
        SetxReport {
            intersection,
            local_unique,
            kind: self.kind,
            converged: true,
            attempts: self.attempt + 1,
            rounds,
            retries: 0,
            retry_bytes: 0,
            comm: self.comm.clone(),
            local_is_alice: self.client,
            trace: self.tracer.trace().clone(),
        }
    }

    fn record_sent(&mut self, msg: &Msg) {
        let (enc, raw) = (msg.wire_len(), msg.raw_wire_len());
        let phase = frame_phase(msg);
        self.comm.record_framed(self.client, phase, enc, raw);
        self.mark_frame(phase);
    }

    fn record_recv(&mut self, msg: &Msg) {
        let (enc, raw) = (msg.wire_len(), msg.raw_wire_len());
        let phase = frame_phase(msg);
        self.comm.record_framed(!self.client, phase, enc, raw);
        self.mark_frame(phase);
    }

    /// Same marker/frame identity as the session's: an instant `Round`/`Confirm` marker
    /// per frame the endpoint itself accounts (uni sketches, confirms, drained rounds),
    /// emitted at the only points that write this [`CommLog`].
    fn mark_frame(&mut self, phase: crate::metrics::Phase) {
        if phase.is_payload() {
            self.tracer.instant(SpanKind::Round);
        } else if phase == crate::metrics::Phase::Confirm {
            self.tracer.instant(SpanKind::Confirm);
        }
    }
}

/// Pump a client/server endpoint pair in-process to completion — deterministic, no
/// threads, no transport. The in-memory counterpart of two [`crate::setx::Setx::run`]
/// calls, and the per-partition primitive of the partitioned driver.
pub(crate) fn drive_endpoints(
    a: &mut Endpoint<'_>,
    b: &mut Endpoint<'_>,
) -> Result<(SetxReport, SetxReport), SetxError> {
    let mut to_b: VecDeque<Msg> = a.start().into();
    let mut to_a: VecDeque<Msg> = b.start().into();
    let mut report_a: Option<SetxReport> = None;
    let mut report_b: Option<SetxReport> = None;
    loop {
        let mut progressed = false;
        if report_a.is_none() {
            if let Some(msg) = to_a.pop_front() {
                progressed = true;
                match a.on_msg(&msg) {
                    Step::Send(msgs) => to_b.extend(msgs),
                    Step::Continue => {}
                    Step::Finish(msgs, report) => {
                        to_b.extend(msgs);
                        report_a = Some(*report);
                    }
                    Step::Fatal(_, err) => return Err(err),
                }
            }
        }
        if report_b.is_none() {
            if let Some(msg) = to_b.pop_front() {
                progressed = true;
                match b.on_msg(&msg) {
                    Step::Send(msgs) => to_a.extend(msgs),
                    Step::Continue => {}
                    Step::Finish(msgs, report) => {
                        to_a.extend(msgs);
                        report_b = Some(*report);
                    }
                    Step::Fatal(_, err) => return Err(err),
                }
            }
        }
        if report_a.is_some() && report_b.is_some() {
            let ra = report_a.take().expect("checked above");
            let rb = report_b.take().expect("checked above");
            return Ok((ra, rb));
        }
        if !progressed {
            // Neither side owes nor holds a frame: the conversation wedged (a driver bug,
            // not peer behavior — surface it as a closed conversation).
            return Err(SetxError::PeerClosed { during: "in-memory drive (stalled)" });
        }
    }
}
