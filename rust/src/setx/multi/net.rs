//! TCP driver for a multi-party round: [`host_round`] runs the coordinator side of an
//! N-party intersection over real sockets, [`join_round`] is the matching spoke dial-in.
//!
//! The coordinator is event-driven but deliberately simpler than the server's poller
//! pool: one reader thread per spoke feeds a single `mpsc` event loop that owns the
//! sans-io [`MultiCoordinator`]. Readers buffer raw bytes and cut frames with
//! [`frame_extent`] (never a blocking mid-frame read), so a stalled spoke can always be
//! dropped at a frame boundary. The per-party deadline consults
//! [`MultiCoordinator::awaiting`] first: a spoke parked at a barrier — idle because it is
//! waiting on *other* parties — is never a timeout candidate, only one the round is
//! actually waiting on. This is the CLI / test harness; the daemon-grade variant is the
//! [`crate::server::SetxServer`] coordinator mode, which multiplexes the same state
//! machine over its non-blocking poller pool.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::Duration;

use super::super::transport::{frame_extent, TcpTransport};
use super::super::{SetxConfig, SetxError, SetxReport};
use super::{MultiCoordinator, MultiError, MultiReport, Party};
use crate::obs::{default_clock, Clock};
use crate::protocol::wire::Msg;

/// How often a blocked reader wakes to notice a shut-down socket or closed event loop.
const READ_TICK: Duration = Duration::from_millis(50);

/// Poll cadence of the coordinator event loop (accepts + deadline scans between events).
const LOOP_TICK: Duration = Duration::from_millis(20);

enum Event {
    Frame(Msg),
    /// The reader's read timed out — a wake-up so the main loop runs its deadline scan.
    Idle,
    /// Clean close, mid-frame corruption, or unparseable frame: the connection is dead.
    Gone,
}

struct Conn {
    write: TcpStream,
    party: Option<u32>,
    /// Last-activity stamp from [`default_clock`], in nanoseconds (not `Instant`, so
    /// deadline arithmetic shares the one observability clock and tests can audit it).
    last_ns: u64,
    open: bool,
}

impl Conn {
    fn close(&mut self) {
        if self.open {
            self.open = false;
            let _ = self.write.shutdown(Shutdown::Both);
        }
    }
}

/// Host one N-party round on an already-bound listener and return the coordinator's
/// [`MultiReport`]. `deadline` bounds *each* wait on a spoke — the join window, and every
/// frame the round is actually awaiting from a party ([`MultiCoordinator::awaiting`]);
/// a spoke that overruns is dropped with [`MultiError::PartyTimeout`] while the other
/// N−1 parties complete.
pub fn host_round(
    listener: &TcpListener,
    cfg: &SetxConfig,
    set: Vec<u64>,
    count: u32,
    deadline: Duration,
) -> Result<MultiReport, MultiError> {
    let io = |e: std::io::Error| MultiError::Party { party: 0, error: SetxError::Io(e) };
    listener.set_nonblocking(true).map_err(io)?;
    let coord = MultiCoordinator::new(cfg, std::sync::Arc::new(set), count)?;
    let clock = default_clock();
    let deadline_ns = u64::try_from(deadline.as_nanos()).unwrap_or(u64::MAX);
    std::thread::scope(|scope| {
        let mut coord = coord;
        let (tx, rx) = mpsc::channel::<(usize, Event)>();
        let mut conns: Vec<Conn> = Vec::new();
        let started_ns = clock.now_ns();
        loop {
            // Accept new spokes while the roster is open; after that, late dialers are
            // turned away at the socket (the daemon mode answers `Busy` instead).
            if coord.roster_open() {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nodelay(true).ok();
                        stream.set_write_timeout(Some(deadline)).ok();
                        if let Ok(read_half) = stream.try_clone() {
                            read_half.set_read_timeout(Some(READ_TICK)).ok();
                            let idx = conns.len();
                            let tx = tx.clone();
                            scope.spawn(move || reader_loop(read_half, idx, tx));
                            conns.push(Conn {
                                write: stream,
                                party: None,
                                last_ns: clock.now_ns(),
                                open: true,
                            });
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                    Err(_) => {}
                }
                if clock.now_ns().saturating_sub(started_ns) >= deadline_ns
                    && coord.roster_open()
                {
                    let frames = coord.deadline_join();
                    deliver(&mut coord, &mut conns, frames);
                }
            }
            // One blocking wait, then drain whatever queued behind it.
            let mut events: Vec<(usize, Event)> = match rx.recv_timeout(LOOP_TICK) {
                Ok(ev) => vec![ev],
                Err(mpsc::RecvTimeoutError::Timeout) => Vec::new(),
                Err(mpsc::RecvTimeoutError::Disconnected) => Vec::new(),
            };
            events.extend(rx.try_iter());
            for (idx, ev) in events {
                handle_event(&mut coord, &mut conns, idx, ev, clock.now_ns());
            }
            // Per-party deadline scan: only spokes the round is awaiting can time out;
            // barrier-parked (or unjoined) connections get their clock refreshed.
            let now_ns = clock.now_ns();
            for idx in 0..conns.len() {
                if !conns[idx].open {
                    continue;
                }
                let Some(party) = conns[idx].party else {
                    if !coord.roster_open() {
                        conns[idx].close();
                    }
                    continue;
                };
                if !coord.awaiting(party) {
                    conns[idx].last_ns = now_ns;
                } else if now_ns.saturating_sub(conns[idx].last_ns) >= deadline_ns {
                    conns[idx].close();
                    let frames = coord.drop_party(party, MultiError::PartyTimeout { party });
                    deliver(&mut coord, &mut conns, frames);
                }
            }
            if coord.is_done() {
                break;
            }
        }
        for conn in &mut conns {
            conn.close();
        }
        // `tx`/`rx` drop here; readers notice within a tick and the scope joins them.
        Ok(coord.into_report())
    })
}

/// Dial into a hosted round as spoke `id` and drive [`Party::run`] to completion,
/// returning this party's own [`SetxReport`] (its view of `∩ᵢSᵢ`).
pub fn join_round(
    addr: impl ToSocketAddrs,
    cfg: &SetxConfig,
    set: Vec<u64>,
    id: u32,
    count: u32,
) -> Result<SetxReport, MultiError> {
    let mut party = Party::new(cfg, set, id, count)?;
    let wrap = |error| MultiError::Party { party: id, error };
    let mut transport = TcpTransport::connect(addr).map_err(wrap)?;
    party.run(&mut transport).map_err(wrap)
}

fn handle_event(
    coord: &mut MultiCoordinator,
    conns: &mut [Conn],
    idx: usize,
    ev: Event,
    now_ns: u64,
) {
    match ev {
        Event::Frame(msg) => {
            conns[idx].last_ns = now_ns;
            match conns[idx].party {
                None => match coord.route_hello(&msg) {
                    Ok((party, frames)) => {
                        conns[idx].party = Some(party);
                        deliver(coord, conns, frames);
                    }
                    // Rejected join (duplicate id, bad count, config mismatch, late
                    // dialer): only this connection dies, the round is untouched.
                    Err(_) => conns[idx].close(),
                },
                Some(party) => {
                    let frames = coord.on_msg(party, &msg);
                    deliver(coord, conns, frames);
                }
            }
        }
        Event::Idle => {}
        Event::Gone => {
            if conns[idx].open {
                conns[idx].close();
                if let Some(party) = conns[idx].party {
                    let frames = coord.drop_party(party, MultiError::PartyTimeout { party });
                    deliver(coord, conns, frames);
                }
            }
        }
    }
}

/// Write coordinator frames out to their spokes. A failed write is a dead spoke: it is
/// dropped from the round, and any frames that releases (other parties' barriers) join
/// the queue.
fn deliver(coord: &mut MultiCoordinator, conns: &mut [Conn], frames: Vec<(u32, Msg)>) {
    let mut pending: VecDeque<(u32, Msg)> = frames.into();
    while let Some((party, msg)) = pending.pop_front() {
        let Some(conn) = conns.iter_mut().find(|c| c.party == Some(party) && c.open) else {
            continue;
        };
        if conn.write.write_all(&msg.to_bytes()).is_err() {
            conn.close();
            pending.extend(coord.drop_party(party, MultiError::PartyTimeout { party }));
        }
    }
}

/// Per-connection reader: buffer raw bytes, cut complete frames with [`frame_extent`],
/// and feed the event loop. Never blocks mid-frame (reads are chunked with a short OS
/// timeout), so the main loop's deadline verdicts always land on a frame boundary.
fn reader_loop(mut stream: TcpStream, idx: usize, tx: mpsc::Sender<(usize, Event)>) {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 8192];
    loop {
        loop {
            match frame_extent(&buf) {
                Ok(Some(len)) => {
                    let rest = buf.split_off(len);
                    let frame = std::mem::replace(&mut buf, rest);
                    match Msg::from_bytes(&frame) {
                        Some((msg, used)) if used == frame.len() => {
                            if tx.send((idx, Event::Frame(msg))).is_err() {
                                return;
                            }
                        }
                        _ => {
                            let _ = tx.send((idx, Event::Gone));
                            return;
                        }
                    }
                }
                Ok(None) => break,
                Err(_) => {
                    let _ = tx.send((idx, Event::Gone));
                    return;
                }
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                let _ = tx.send((idx, Event::Gone));
                return;
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                if tx.send((idx, Event::Idle)).is_err() {
                    return;
                }
            }
            Err(_) => {
                let _ = tx.send((idx, Event::Gone));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::n_sets;
    use super::*;
    use crate::setx::Setx;

    fn expected_intersection(sets: &[Vec<u64>]) -> Vec<u64> {
        let mut out: Vec<u64> = sets[0]
            .iter()
            .copied()
            .filter(|x| sets[1..].iter().all(|s| s.contains(x)))
            .collect();
        out.sort_unstable();
        out
    }

    #[test]
    fn tcp_round_three_parties_all_learn_the_intersection() {
        let sets = n_sets(3, 500, 10, 0xD1A1);
        let cfg = *Setx::builder(&sets[0]).build().unwrap().config();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let spokes: Vec<_> = (1u32..3)
            .map(|id| {
                let set = sets[id as usize].clone();
                std::thread::spawn(move || join_round(addr, &cfg, set, id, 3))
            })
            .collect();
        let report =
            host_round(&listener, &cfg, sets[0].clone(), 3, Duration::from_secs(10)).unwrap();
        let expect = expected_intersection(&sets);
        assert_eq!(report.intersection, expect);
        assert_eq!(report.completed(), 2);
        let sum: usize = report.parties.iter().map(|p| p.total_bytes()).sum();
        assert_eq!(sum, report.total_bytes());
        for h in spokes {
            let r = h.join().unwrap().unwrap();
            assert_eq!(r.intersection, expect);
        }
    }

    #[test]
    fn stalled_spoke_times_out_and_the_rest_complete() {
        let sets = n_sets(3, 400, 8, 0x57A1);
        let cfg = *Setx::builder(&sets[0]).build().unwrap().config();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // Spoke 2 joins (so the roster completes) and then goes silent mid-round.
        let stall_set = sets[2].clone();
        let staller = std::thread::spawn(move || {
            let mut party = Party::new(&cfg, stall_set, 2, 3).unwrap();
            let mut s = TcpStream::connect(addr).unwrap();
            for m in party.start() {
                s.write_all(&m.to_bytes()).unwrap();
            }
            std::thread::sleep(Duration::from_millis(2500));
            drop(s);
        });
        let live_set = sets[1].clone();
        let live = std::thread::spawn(move || join_round(addr, &cfg, live_set, 1, 3));
        let report =
            host_round(&listener, &cfg, sets[0].clone(), 3, Duration::from_millis(700)).unwrap();
        // The committed intersection covers the parties that stayed: coordinator + spoke 1.
        let expect = expected_intersection(&sets[..2]);
        assert_eq!(report.intersection, expect);
        assert_eq!(report.completed(), 1);
        let timed_out = report.parties.iter().find(|p| p.party == 2).unwrap();
        assert!(
            matches!(timed_out.error, Some(MultiError::PartyTimeout { party: 2 })),
            "stalled spoke must surface PartyTimeout, got {:?}",
            timed_out.error
        );
        assert!(report.parties.iter().find(|p| p.party == 1).unwrap().error.is_none());
        let r1 = live.join().unwrap().unwrap();
        assert_eq!(r1.intersection, expect);
        staller.join().unwrap();
    }

    #[test]
    fn empty_roster_round_closes_at_the_join_deadline() {
        let cfg = *Setx::builder(&[1, 2, 3]).build().unwrap().config();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let report = host_round(
            &listener,
            &cfg,
            vec![3, 1, 2],
            3,
            Duration::from_millis(200),
        )
        .unwrap();
        // Nobody dialed in: the round degenerates to the coordinator's own set.
        assert_eq!(report.intersection, vec![1, 2, 3]);
        assert!(report.parties.is_empty());
    }
}
