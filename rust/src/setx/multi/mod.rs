//! **Multi-party SetX**: one coordinator, N−1 spokes, everyone learns `∩ᵢSᵢ`.
//!
//! The two-party protocol reconciles a *pair* of sets through one linear CS sketch
//! exchange. Linearity is what generalizes it: a sum of sketches is the sketch of the
//! multiset union, so a single coordinator can collect every party's sketch under one
//! shared matrix, aggregate them, and repair each spoke against its own residue — a star
//! topology with per-spoke failure and escalation isolation (the same receiver/N−1-sender
//! shape the multi-party PSI literature settles on, and exactly the topology
//! [`crate::server::SetxServer`] already serves).
//!
//! ```text
//!                    S₁ ─╮  EstHello(party 1/N) + sketch
//!        S₂ ──────────── C ── aggregate Σ sk(Sᵢ), per-spoke repair, membership
//!                    S₃ ─╯  ⇒ every party holds ∩ᵢSᵢ
//! ```
//!
//! ## Round structure
//!
//! 1. **Join** — each spoke opens with the two-party `EstHello` frame plus the versioned
//!    `party: (id, count)` trailing varints; the coordinator answers with its own hello,
//!    and both ends run the ordinary estimator negotiation per spoke.
//! 2. **Collect** — once all parties joined, the coordinator fixes one shared collect
//!    geometry (sized for the *worst* spoke's estimated difference) and every spoke sends
//!    its compressed CS sketch under it.
//! 3. **Aggregate + repair** — the coordinator recovers each spoke's counts against its
//!    own sketch, forms the aggregate `Σᵢ sk(Sᵢ)`, and broadcasts an
//!    [`Msg::AggSketch`] barrier telling each spoke whether its per-party residue was
//!    zero. Out-of-sync spokes run a full inner two-party session (same `Session`
//!    engine, same l-escalation ladder) to exchange exact differences. Note the
//!    *aggregate itself* is never used as a sync test — `sk(S₁)+sk(S₂) = 2·sk(C)` also
//!    holds for `S₁ = C∪{x}, S₂ = C∖{x}` — sync is decided per party.
//! 4. **Membership** — knowing every `C∖Sᵢ`, the coordinator computes
//!    `∩ = C ∖ ∪ᵢ(C∖Sᵢ)` and tells each spoke exactly which of its pairwise-common
//!    elements dropped out, as a compressed sketch of `∩` decoded against the spoke's
//!    candidates ([`Msg::MultiResidue`], per-spoke escalation ladder).
//! 5. **Final confirm** — once every live spoke acknowledged, a last `Confirm` broadcast
//!    certifies that all N parties agree on `∩ᵢSᵢ`.
//!
//! A stalled or disconnected spoke is dropped from the round with
//! [`MultiError::PartyTimeout`] instead of wedging the other N−1 — see
//! [`MultiCoordinator::awaiting`] and [`MultiCoordinator::drop_party`].
//!
//! Entry points: [`crate::setx::Setx::multi`] / [`crate::setx::SetxBuilder::parties`]
//! (in-process), [`net::host_round`] / [`net::join_round`] (TCP), and the
//! [`crate::server::ServerBuilder::multi_tenant`] coordinator mode (daemon).

pub mod net;

use std::collections::{BTreeMap, HashSet};
use std::sync::Arc;

use crate::decoder::DecoderCache;
use crate::entropy::{compress_sketch, recover_sketch};
use crate::hash::hash_u64;
use crate::metrics::CommLog;
use crate::obs::{SessionTrace, SpanKind, Tracer};
use crate::protocol::session::{codec_params, frame_phase};
use crate::protocol::wire::{Msg, DIRECTIVE_IN_SYNC, DIRECTIVE_SESSION, REASON_OK};
use crate::protocol::{uni, wire_geometry_ok, CsParams};
use crate::sketch::{EncodeConfig, Sketch};

use super::endpoint::{
    build_est_hello, failure_to_reason, negotiate, reason_to_failure, Endpoint, Negotiated, Step,
};
use super::{ProtocolKind, Setx, SetxConfig, SetxError, SetxReport};

/// Upper bound on `party_count` accepted by coordinator and spokes — far above any
/// deployment, low enough that an adversarial count cannot size allocations.
pub const MAX_PARTIES: u32 = 1 << 16;

/// Soft cap on the `AggSketch` frame: the aggregate counts ride along only while the
/// whole frame stays under this, otherwise the digest-only form is sent.
const AGG_COUNTS_BUDGET: usize = 64 << 10;

/// Collect-phase matrix seed: derived from the config seed but disjoint from every
/// two-party attempt seed (which perturbs `cfg.seed` by attempt multiples).
fn collect_seed(seed: u64) -> u64 {
    hash_u64(seed, 0xA66C_5EED_0000_0001)
}

/// Per-(party, rung) membership-sketch seed — each retry and each spoke gets a fresh
/// matrix so a pathological column layout cannot pin a spoke's ladder.
fn membership_seed(seed: u64, party: u32, attempt: u32) -> u64 {
    hash_u64(seed ^ (((party as u64) << 32) | attempt as u64), 0xA66D_5EED_0000_0002)
}

/// Order-sensitive hash fold over aggregate counts (coordinate i at position i).
fn agg_digest(counts: &[i64], seed: u64) -> u64 {
    let mut h = 0xA66D_1665_u64 ^ seed;
    for &c in counts {
        h = hash_u64(h ^ (c as u64), seed);
    }
    h
}

/// The typed error surface of the multi-party facade.
#[derive(Debug)]
pub enum MultiError {
    /// Builder/validation failure (party counts, config ranges).
    Config(String),
    /// A spoke tried to claim a party id that is already joined. The offending
    /// connection is rejected; the round (and the first claimer) stay intact.
    DuplicateParty { party: u32 },
    /// A spoke stalled past the round deadline (or disconnected) while the round was
    /// waiting on it, and was dropped so the other N−1 parties could proceed.
    PartyTimeout { party: u32 },
    /// A join arrived after the round left its join phase.
    RoundInProgress,
    /// A spoke failed with an ordinary two-party error (config mismatch, malformed
    /// frame, exhausted decode ladder, transport I/O).
    Party { party: u32, error: SetxError },
}

impl std::fmt::Display for MultiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MultiError::Config(why) => write!(f, "invalid multi-party config: {why}"),
            MultiError::DuplicateParty { party } => {
                write!(f, "party id {party} already joined this round")
            }
            MultiError::PartyTimeout { party } => {
                write!(f, "party {party} stalled past the round deadline and was dropped")
            }
            MultiError::RoundInProgress => write!(f, "round already past its join phase"),
            MultiError::Party { party, error } => write!(f, "party {party}: {error}"),
        }
    }
}

impl MultiError {
    /// Whether rejoining a fresh round can plausibly succeed — the N-party face
    /// of [`SetxError::is_transient`]. A stalled/dropped spoke
    /// ([`MultiError::PartyTimeout`]) and a round that was merely full or past
    /// its join window ([`MultiError::RoundInProgress`]) are worth a retry; a
    /// spoke error delegates to its inner classification; config and
    /// duplicate-id faults reproduce as-is.
    pub fn is_transient(&self) -> bool {
        match self {
            MultiError::PartyTimeout { .. } | MultiError::RoundInProgress => true,
            MultiError::Party { error, .. } => error.is_transient(),
            MultiError::Config(_) | MultiError::DuplicateParty { .. } => false,
        }
    }
}

impl std::error::Error for MultiError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MultiError::Party { error, .. } => Some(error),
            _ => None,
        }
    }
}

/// Per-spoke outcome inside a [`MultiReport`].
#[derive(Debug)]
pub struct PartyOutcome {
    /// The spoke's party id (1-based; 0 is the coordinator itself).
    pub party: u32,
    /// Exact transcript of every frame exchanged with this spoke — handshake, collect,
    /// inner repair session, membership, final confirm — at wire sizes.
    pub comm: CommLog,
    /// Membership-ladder rungs used (0 = the spoke's pairwise-common set was exactly the
    /// intersection, so a bare confirm sufficed).
    pub attempts: u32,
    /// The spoke's collect sketch matched the coordinator's set bit-exactly (zero
    /// per-party residue — the fast path that skips the inner session).
    pub synced: bool,
    /// Why this spoke did not complete the round, if it did not. Parties dropped after
    /// the intersection was committed keep the committed value out of the result.
    pub error: Option<MultiError>,
}

impl PartyOutcome {
    /// Total bytes exchanged with this spoke, both directions.
    pub fn total_bytes(&self) -> usize {
        self.comm.total_bytes()
    }

    /// Codec-off-equivalent bytes for this spoke (equal to
    /// [`PartyOutcome::total_bytes`] when the spoke negotiated the codec off).
    pub fn total_raw_bytes(&self) -> usize {
        self.comm.total_raw_bytes()
    }
}

/// Outcome of a multi-party round at the coordinator.
#[derive(Debug)]
pub struct MultiReport {
    /// `∩ᵢSᵢ` over the coordinator and every spoke whose difference constraint
    /// committed, sorted ascending.
    pub intersection: Vec<u64>,
    /// One entry per spoke, in party-id order.
    pub parties: Vec<PartyOutcome>,
    /// Concatenation of every spoke's transcript — per-party bytes sum to this total by
    /// construction.
    pub comm: CommLog,
    /// Coordinator timeline: one `MultiJoin`/`MultiCollect`/`MultiConstraint`/
    /// `MultiFinal` span per round phase (barrier to barrier). Empty when the config ran
    /// with `tracing` off. See [`crate::obs`].
    pub trace: SessionTrace,
}

impl MultiReport {
    /// Total conversation bytes across every spoke, both directions.
    pub fn total_bytes(&self) -> usize {
        self.comm.total_bytes()
    }

    /// What the round *would* have cost without the columnar wire codec.
    pub fn total_raw_bytes(&self) -> usize {
        self.comm.total_raw_bytes()
    }

    /// Encoded ÷ raw bytes across every spoke (1.0 = codec off or no savings).
    pub fn compression_ratio(&self) -> f64 {
        self.comm.compression_ratio()
    }

    /// How many spokes completed the round (coordinator excluded).
    pub fn completed(&self) -> usize {
        self.parties.iter().filter(|p| p.error.is_none()).count()
    }
}

/// Coordinator-side view of one spoke.
enum SpokeState {
    /// Joined and negotiated; waiting for the join barrier to fix the collect geometry.
    Joined,
    /// Collect `Hello` out; awaiting the spoke's compressed sketch.
    AwaitSketch,
    /// Sketch absorbed (recovered or not); waiting for the collect barrier.
    Sketched,
    /// Inner two-party repair session in flight.
    Session(Box<Endpoint<'static>>),
    /// `C∖Sᵢ` known; waiting for the constraint barrier (the intersection commit).
    Constrained,
    /// Membership frame out; awaiting the spoke's verdict for this ladder rung.
    AwaitVerdict { attempt: u32 },
    /// Spoke acknowledged the membership round; waiting for the final barrier.
    Settled,
    /// Terminal (final confirm sent, or dropped/failed).
    Done,
}

struct Spoke {
    state: SpokeState,
    nego: Negotiated,
    comm: CommLog,
    /// Collect params for this spoke (shared matrix; per-spoke entropy codec).
    params: Option<CsParams>,
    /// `C ∖ Sᵢ` once the constraint committed.
    unique: Vec<u64>,
    /// `Kᵢ ∖ ∩` — fixed at the membership barrier, re-sketched on every ladder rung.
    drop: Vec<u64>,
    kept_len: usize,
    synced: bool,
    /// Collect recovery failed — treated as out-of-sync and excluded from the aggregate.
    needs_session: bool,
    attempts: u32,
    error: Option<MultiError>,
}

impl Spoke {
    fn live(&self) -> bool {
        self.error.is_none()
    }
}

/// Sans-io multi-party coordinator state machine. Feed it spoke frames via
/// [`MultiCoordinator::route_hello`] (first frame of a connection) and
/// [`MultiCoordinator::on_msg`]; it returns `(party, frame)` pairs the driver must
/// deliver. Works unchanged under the in-process pump, the threaded TCP harness
/// ([`net::host_round`]), and the server's poller pool.
pub struct MultiCoordinator {
    cfg: SetxConfig,
    set: Arc<Vec<u64>>,
    /// The coordinator's set, sorted (set-algebra phases want deterministic order).
    sorted: Vec<u64>,
    count: u32,
    hello: Msg,
    ests: Option<(
        crate::protocol::estimate::StrataEstimator,
        crate::protocol::estimate::MinHashEstimator,
    )>,
    enc: EncodeConfig,
    spokes: BTreeMap<u32, Spoke>,
    /// No further joins: the join barrier fired (all parties present or deadline).
    joins_closed: bool,
    collect_sent: bool,
    directives_sent: bool,
    finals_sent: bool,
    /// `sk(C)` under the shared collect geometry.
    sketch_c: Option<Sketch>,
    /// Running aggregate `Σᵢ sk(Sᵢ)` (i64: a hostile spoke's recovered counts must not
    /// overflow the fold).
    agg: Vec<i64>,
    parties_in_agg: u32,
    intersection: Option<Vec<u64>>,
    /// Round-phase timeline: each barrier in [`MultiCoordinator::advance`] closes the
    /// current phase span and opens the next.
    tracer: Tracer,
}

impl MultiCoordinator {
    /// A coordinator holding set `C` (party 0) for a round of `count` parties total.
    pub fn new(cfg: &SetxConfig, set: Arc<Vec<u64>>, count: u32) -> Result<Self, MultiError> {
        if !(2..=MAX_PARTIES).contains(&count) {
            return Err(MultiError::Config(format!(
                "party count {count} outside [2, {MAX_PARTIES}]"
            )));
        }
        let (mut hello, ests) = build_est_hello(cfg, &set);
        if let Msg::EstHello { party, .. } = &mut hello {
            *party = Some((0, count));
        }
        let mut sorted = (*set).clone();
        sorted.sort_unstable();
        let mut tracer = if cfg.tracing { Tracer::new() } else { Tracer::disabled() };
        tracer.open(SpanKind::MultiJoin);
        Ok(MultiCoordinator {
            cfg: *cfg,
            set,
            sorted,
            count,
            hello,
            ests,
            enc: EncodeConfig { threads: cfg.encode_threads },
            spokes: BTreeMap::new(),
            joins_closed: false,
            collect_sent: false,
            directives_sent: false,
            finals_sent: false,
            sketch_c: None,
            agg: Vec::new(),
            parties_in_agg: 1,
            intersection: None,
            tracer,
        })
    }

    /// Feed the opening frame of a new connection. On success returns the claimed party
    /// id plus frames to deliver (the coordinator's own hello, and — when this join
    /// completes the roster — the collect broadcast). On error the *connection* is
    /// rejected; the round and every joined spoke stay intact.
    pub fn route_hello(&mut self, msg: &Msg) -> Result<(u32, Vec<(u32, Msg)>), MultiError> {
        let Msg::EstHello {
            config_fingerprint,
            set_len,
            explicit_d,
            strata,
            minhash,
            namespace,
            party: Some((id, count)),
            codec,
        } = msg
        else {
            return Err(MultiError::Party {
                party: 0,
                error: SetxError::MalformedFrame("multi-party join must open with a party hello"),
            });
        };
        let (id, count) = (*id, *count);
        let reject = |error| MultiError::Party { party: id, error };
        if count != self.count || id == 0 {
            return Err(reject(SetxError::MalformedFrame("party id/count mismatch")));
        }
        if self.joins_closed {
            return Err(MultiError::RoundInProgress);
        }
        if self.spokes.contains_key(&id) {
            return Err(MultiError::DuplicateParty { party: id });
        }
        let ours = self.cfg.fingerprint();
        if *config_fingerprint != ours {
            return Err(reject(SetxError::ConfigMismatch { ours, theirs: *config_fingerprint }));
        }
        if *namespace != self.cfg.namespace() {
            return Err(reject(SetxError::MalformedFrame("party hello namespace mismatch")));
        }
        let Ok(peer_len) = usize::try_from(*set_len) else {
            return Err(reject(SetxError::MalformedFrame("set_len")));
        };
        let nego = negotiate(
            &self.cfg,
            false,
            self.set.len(),
            self.ests.as_ref(),
            peer_len,
            *explicit_d,
            strata.as_deref(),
            minhash.as_deref(),
            *codec,
        )
        .map_err(reject)?;
        let mut spoke = Spoke {
            state: SpokeState::Joined,
            nego,
            comm: CommLog::new(),
            params: None,
            unique: Vec::new(),
            drop: Vec::new(),
            kept_len: 0,
            synced: false,
            needs_session: false,
            attempts: 0,
            error: None,
        };
        log_frame(&mut spoke.comm, true, msg);
        log_frame(&mut spoke.comm, false, &self.hello);
        self.spokes.insert(id, spoke);
        let mut out = vec![(id, self.hello.clone())];
        out.extend(self.advance());
        Ok((id, out))
    }

    /// The join deadline fired: proceed with whoever joined. Missing party ids are *not*
    /// marked failed (they never existed as connections) — the round simply runs with
    /// the present roster.
    pub fn deadline_join(&mut self) -> Vec<(u32, Msg)> {
        self.joins_closed = true;
        self.advance()
    }

    /// True while the round still expects a frame from this spoke. The per-connection
    /// deadline machinery must consult this before dropping: a spoke parked at a barrier
    /// (waiting on *other* parties) is idle legitimately.
    pub fn awaiting(&self, party: u32) -> bool {
        match self.spokes.get(&party).filter(|s| s.live()).map(|s| &s.state) {
            Some(SpokeState::AwaitSketch)
            | Some(SpokeState::Session(_))
            | Some(SpokeState::AwaitVerdict { .. }) => true,
            Some(_) | None => false,
        }
    }

    /// Whether `party` has joined (and not been dropped).
    pub fn joined(&self, party: u32) -> bool {
        self.spokes.get(&party).is_some_and(|s| s.live())
    }

    /// Whether the round still accepts joins (roster incomplete and no join deadline
    /// yet). Drivers use this to gate their accept loop.
    pub fn roster_open(&self) -> bool {
        !self.joins_closed
    }

    /// Drop a spoke from the round (timeout, disconnect): every other party proceeds. A
    /// spoke dropped before the intersection commits is excluded from it; one dropped
    /// after keeps the committed value intact and is merely reported failed.
    pub fn drop_party(&mut self, party: u32, err: MultiError) -> Vec<(u32, Msg)> {
        if let Some(spoke) = self.spokes.get_mut(&party) {
            // A spoke that already completed the round (`Done` without error) is immune:
            // its transport closing after the final confirm is the normal teardown.
            if spoke.live() && !matches!(spoke.state, SpokeState::Done) {
                spoke.error = Some(err);
                spoke.state = SpokeState::Done;
            }
        }
        self.advance()
    }

    /// The round finished: every spoke is settled, failed, or dropped.
    pub fn is_done(&self) -> bool {
        self.finals_sent
            || (self.joins_closed
                && self
                    .spokes
                    .values()
                    .all(|s| matches!(s.state, SpokeState::Done)))
    }

    /// Feed one frame from a joined spoke.
    pub fn on_msg(&mut self, party: u32, msg: &Msg) -> Vec<(u32, Msg)> {
        let Some(spoke) = self.spokes.get_mut(&party) else {
            return Vec::new();
        };
        if !spoke.live() {
            return Vec::new();
        }
        let mut out: Vec<(u32, Msg)> = Vec::new();
        match (std::mem::replace(&mut spoke.state, SpokeState::Done), msg) {
            (SpokeState::AwaitSketch, Msg::Sketch { sketch: sk_msg, .. }) => {
                log_frame(&mut spoke.comm, true, msg);
                let params = spoke.params.as_ref().expect("collect params set with hello");
                let counts = &self.sketch_c.as_ref().expect("sk(C) encoded at collect").counts;
                let recovered = (sk_msg.n == counts.len())
                    .then(|| recover_sketch(sk_msg, counts, &codec_params(params, true)))
                    .flatten();
                match recovered {
                    Some((x_hat, _, _)) => {
                        spoke.synced = counts.iter().zip(&x_hat).all(|(c, x)| c == x);
                        for (a, x) in self.agg.iter_mut().zip(&x_hat) {
                            *a += *x as i64;
                        }
                        self.parties_in_agg += 1;
                    }
                    None => {
                        // Could not reconcile the spoke's sketch with ours: exclude it
                        // from the aggregate and let the inner session repair the pair.
                        spoke.needs_session = true;
                    }
                }
                spoke.state = SpokeState::Sketched;
            }
            (SpokeState::Session(mut ep), _) => match ep.on_msg(msg) {
                Step::Send(msgs) => {
                    spoke.state = SpokeState::Session(ep);
                    out.extend(msgs.into_iter().map(|m| (party, m)));
                }
                Step::Continue => spoke.state = SpokeState::Session(ep),
                Step::Finish(msgs, report) => {
                    out.extend(msgs.into_iter().map(|m| (party, m)));
                    spoke.comm.extend(&report.comm);
                    spoke.attempts = report.attempts;
                    spoke.unique = report.local_unique;
                    spoke.state = SpokeState::Constrained;
                }
                Step::Fatal(msgs, error) => {
                    out.extend(msgs.into_iter().map(|m| (party, m)));
                    spoke.error = Some(MultiError::Party { party, error });
                }
            },
            (SpokeState::AwaitVerdict { attempt }, Msg::Confirm { ok, reason, attempt: a }) => {
                log_frame(&mut spoke.comm, true, msg);
                if *a != attempt {
                    spoke.error = Some(MultiError::Party {
                        party,
                        error: SetxError::MalformedFrame("membership confirm attempt skew"),
                    });
                } else if *ok {
                    spoke.state = SpokeState::Settled;
                } else if attempt + 1 < self.cfg.max_attempts {
                    let next = attempt + 1;
                    let frame = membership_frame(
                        &self.cfg,
                        self.enc,
                        self.intersection.as_ref().expect("membership implies commit"),
                        party,
                        next,
                        spoke.kept_len,
                        &spoke.drop,
                        spoke.nego.codec,
                    );
                    log_frame(&mut spoke.comm, false, &frame);
                    spoke.attempts = next + 1;
                    spoke.state = SpokeState::AwaitVerdict { attempt: next };
                    out.push((party, frame));
                } else {
                    // Ladder exhausted: echo the verdict as a teardown so the spoke sees
                    // a terminal Confirm (not a silent close), then fail the party.
                    let frame = Msg::Confirm { ok: false, reason: *reason, attempt };
                    log_frame(&mut spoke.comm, false, &frame);
                    out.push((party, frame));
                    spoke.error = Some(MultiError::Party {
                        party,
                        error: SetxError::Decode {
                            failure: reason_to_failure(*reason),
                            attempts: attempt + 1,
                        },
                    });
                }
            }
            (_, _) => {
                log_frame(&mut spoke.comm, true, msg);
                spoke.error = Some(MultiError::Party {
                    party,
                    error: SetxError::MalformedFrame("frame out of phase for this spoke"),
                });
            }
        }
        out.extend(self.advance());
        out
    }

    /// Run every barrier that can fire, in order, returning the frames it produces.
    fn advance(&mut self) -> Vec<(u32, Msg)> {
        let mut out = Vec::new();
        // Join barrier: full roster (or deadline) → fix the shared collect geometry.
        if !self.collect_sent
            && (self.joins_closed || self.spokes.len() as u32 == self.count - 1)
        {
            self.joins_closed = true;
            self.collect_sent = true;
            self.tracer.close(SpanKind::MultiJoin);
            self.tracer.open(SpanKind::MultiCollect);
            let live: Vec<u32> = self.live_ids();
            if !live.is_empty() {
                // One matrix for every spoke, sized for the worst estimated difference.
                let l = live
                    .iter()
                    .map(|id| {
                        let n = self.spokes[id].nego;
                        CsParams::tuned_uni_with_safety(n.n_union, n.d_hat, self.cfg.safety).l
                    })
                    .max()
                    .unwrap_or(1);
                let seed = collect_seed(self.cfg.seed);
                let base = CsParams {
                    l,
                    m: 7,
                    seed,
                    universe_bits: self.cfg.universe_bits,
                    est_a_unique: 0,
                    est_b_unique: 0,
                };
                let sk = Sketch::encode_par(base.matrix(), &self.set, self.enc);
                self.agg = sk.counts.iter().map(|&c| c as i64).collect();
                self.sketch_c = Some(sk);
                for id in live {
                    let spoke = self.spokes.get_mut(&id).expect("live id");
                    let params = CsParams {
                        est_a_unique: spoke.nego.est_peer,
                        est_b_unique: spoke.nego.est_local,
                        ..base
                    };
                    let hello = Msg::Hello {
                        l: params.l,
                        m: params.m,
                        seed: params.seed,
                        universe_bits: params.universe_bits,
                        est_initiator_unique: params.est_a_unique as u64,
                        est_responder_unique: params.est_b_unique as u64,
                        set_len: self.set.len() as u64,
                        namespace: self.cfg.namespace(),
                    };
                    log_frame(&mut spoke.comm, false, &hello);
                    spoke.params = Some(params);
                    spoke.state = SpokeState::AwaitSketch;
                    out.push((id, hello));
                }
            }
        }
        // Collect barrier: every live spoke sketched → aggregate + directives.
        if self.collect_sent
            && !self.directives_sent
            && self.live_states_none(|s| matches!(s, SpokeState::AwaitSketch | SpokeState::Joined))
        {
            self.directives_sent = true;
            self.tracer.close(SpanKind::MultiCollect);
            self.tracer.open(SpanKind::MultiConstraint);
            let digest = agg_digest(&self.agg, collect_seed(self.cfg.seed));
            let counts32: Option<Vec<i32>> = self
                .agg
                .iter()
                .map(|&c| i32::try_from(c).ok())
                .collect::<Option<Vec<i32>>>();
            for id in self.live_ids() {
                let spoke = self.spokes.get_mut(&id).expect("live id");
                let params = spoke.params.as_ref().expect("collect params");
                let session = spoke.needs_session || !spoke.synced;
                let mut frame = Msg::AggSketch {
                    parties: self.parties_in_agg.max(2),
                    l: params.l,
                    m: params.m,
                    seed: params.seed,
                    digest,
                    directive: if session { DIRECTIVE_SESSION } else { DIRECTIVE_IN_SYNC },
                    counts: counts32.clone(),
                    codec: spoke.nego.codec,
                };
                if frame.wire_len() > AGG_COUNTS_BUDGET {
                    if let Msg::AggSketch { counts, .. } = &mut frame {
                        *counts = None;
                    }
                }
                log_frame(&mut spoke.comm, false, &frame);
                out.push((id, frame));
                if session {
                    let mut ep = Endpoint::new_owned_negotiated(
                        self.cfg,
                        self.set.clone(),
                        false,
                        spoke.nego,
                    );
                    ep.set_encode(self.enc);
                    out.extend(ep.start().into_iter().map(|m| (id, m)));
                    spoke.state = SpokeState::Session(Box::new(ep));
                } else {
                    spoke.unique = Vec::new();
                    spoke.state = SpokeState::Constrained;
                }
            }
        }
        // Constraint barrier: every live spoke's `C∖Sᵢ` committed → intersection +
        // membership round.
        if self.directives_sent
            && self.intersection.is_none()
            && self.live_states_none(|s| {
                matches!(
                    s,
                    SpokeState::Session(_) | SpokeState::Sketched | SpokeState::AwaitSketch
                )
            })
        {
            self.tracer.close(SpanKind::MultiConstraint);
            self.tracer.open(SpanKind::MultiFinal);
            let mut gone: HashSet<u64> = HashSet::new();
            for spoke in self.spokes.values().filter(|s| s.live()) {
                gone.extend(spoke.unique.iter().copied());
            }
            let inter: Vec<u64> =
                self.sorted.iter().copied().filter(|x| !gone.contains(x)).collect();
            let inter_set: HashSet<u64> = inter.iter().copied().collect();
            for (&id, spoke) in self.spokes.iter_mut().filter(|(_, s)| s.live()) {
                let mine: HashSet<u64> = spoke.unique.iter().copied().collect();
                let kept: Vec<u64> =
                    self.sorted.iter().copied().filter(|x| !mine.contains(x)).collect();
                spoke.drop = kept.iter().copied().filter(|x| !inter_set.contains(x)).collect();
                spoke.kept_len = kept.len();
                let frame = if spoke.drop.is_empty() {
                    // The spoke's pairwise-common set IS the intersection.
                    Msg::Confirm { ok: true, reason: REASON_OK, attempt: 0 }
                } else {
                    membership_frame(
                        &self.cfg,
                        self.enc,
                        &inter,
                        id,
                        0,
                        spoke.kept_len,
                        &spoke.drop,
                        spoke.nego.codec,
                    )
                };
                if !spoke.drop.is_empty() {
                    spoke.attempts = 1;
                }
                log_frame(&mut spoke.comm, false, &frame);
                spoke.state = SpokeState::AwaitVerdict { attempt: 0 };
                out.push((id, frame));
            }
            self.intersection = Some(inter);
        }
        // Final barrier: every live spoke settled → certify the round to all of them.
        if self.intersection.is_some()
            && !self.finals_sent
            && self.live_states_none(|s| matches!(s, SpokeState::AwaitVerdict { .. }))
        {
            self.finals_sent = true;
            self.tracer.close(SpanKind::MultiFinal);
            for id in self.live_ids() {
                let spoke = self.spokes.get_mut(&id).expect("live id");
                if matches!(spoke.state, SpokeState::Settled) {
                    let frame = Msg::Confirm { ok: true, reason: REASON_OK, attempt: 0 };
                    log_frame(&mut spoke.comm, false, &frame);
                    spoke.state = SpokeState::Done;
                    out.push((id, frame));
                }
            }
        }
        out
    }

    fn live_ids(&self) -> Vec<u32> {
        self.spokes.iter().filter(|(_, s)| s.live()).map(|(&id, _)| id).collect()
    }

    /// No live spoke is in a state matching `pred`.
    fn live_states_none(&self, pred: impl Fn(&SpokeState) -> bool) -> bool {
        !self.spokes.values().any(|s| s.live() && pred(&s.state))
    }

    /// Consume the coordinator into its report. Call once [`MultiCoordinator::is_done`];
    /// earlier calls report the round as it stands (unfinished spokes show errors).
    pub fn into_report(mut self) -> MultiReport {
        let trace = self.tracer.take();
        let intersection = self.intersection.unwrap_or_else(|| self.sorted.clone());
        let mut comm = CommLog::new();
        let parties: Vec<PartyOutcome> = self
            .spokes
            .into_iter()
            .map(|(party, spoke)| {
                comm.extend(&spoke.comm);
                PartyOutcome {
                    party,
                    comm: spoke.comm,
                    attempts: spoke.attempts,
                    synced: spoke.synced && !spoke.needs_session,
                    error: spoke.error,
                }
            })
            .collect();
        MultiReport { intersection, parties, comm, trace }
    }
}

/// Charge one frame to a transcript at both its encoded and codec-off-equivalent sizes.
fn log_frame(comm: &mut CommLog, inbound: bool, msg: &Msg) {
    comm.record_framed(inbound, frame_phase(msg), msg.wire_len(), msg.raw_wire_len());
}

/// Build one membership frame: a compressed sketch of the intersection, sized for this
/// spoke's exact drop count with the rung's escalated safety factor.
fn membership_frame(
    cfg: &SetxConfig,
    enc: EncodeConfig,
    intersection: &[u64],
    party: u32,
    attempt: u32,
    kept_len: usize,
    drop: &[u64],
    wire_codec: bool,
) -> Msg {
    let mut params = CsParams::tuned_uni_with_safety(
        kept_len.max(1),
        drop.len().max(1),
        cfg.safety * SetxConfig::ladder_factor(attempt),
    );
    params.seed = membership_seed(cfg.seed, party, attempt);
    params.universe_bits = cfg.universe_bits;
    let codec = codec_params(&params, true);
    let sketch = Sketch::encode_par(params.matrix(), intersection, enc);
    Msg::MultiResidue {
        party,
        attempt,
        l: params.l,
        m: params.m,
        seed: params.seed,
        universe_bits: params.universe_bits,
        est_drop: drop.len() as u64,
        sketch: compress_sketch(&sketch.counts, &codec),
        codec: wire_codec,
    }
}

/// Spoke-side phase.
enum PartyPhase {
    /// Our party hello is out; awaiting the coordinator's.
    AwaitCoordHello,
    /// Negotiated; awaiting the shared collect geometry.
    AwaitCollectHello,
    /// Collect sketch sent; awaiting the aggregate barrier + directive.
    AwaitDirective { params: CsParams },
    /// Inner two-party repair session in flight.
    Session(Box<Endpoint<'static>>),
    /// Constraint done; awaiting the membership verdict (sketch or bare confirm).
    AwaitMembership,
    /// Intersection known and acknowledged; awaiting the final round certificate.
    AwaitFinal,
    /// Terminal.
    Done,
}

fn party_phase_name(phase: &PartyPhase) -> &'static str {
    match phase {
        PartyPhase::AwaitCoordHello => "await-coordinator-hello",
        PartyPhase::AwaitCollectHello => "await-collect-hello",
        PartyPhase::AwaitDirective { .. } => "await-aggregate",
        PartyPhase::Session(_) => "inner-session",
        PartyPhase::AwaitMembership => "await-membership",
        PartyPhase::AwaitFinal => "await-final-confirm",
        PartyPhase::Done => "done",
    }
}

/// One spoke endpoint of a multi-party round, driven over any
/// [`super::transport::Transport`] via [`Party::run`] (or sans-io via
/// [`Party::start`]/[`Party::on_msg`], which is how the in-process pump drives it).
pub struct Party {
    cfg: SetxConfig,
    set: Arc<Vec<u64>>,
    sorted: Vec<u64>,
    id: u32,
    count: u32,
    phase: PartyPhase,
    comm: CommLog,
    ests: Option<(
        crate::protocol::estimate::StrataEstimator,
        crate::protocol::estimate::MinHashEstimator,
    )>,
    nego: Option<Negotiated>,
    cache: DecoderCache,
    enc: EncodeConfig,
    /// `Sᵢ ∖ C` from the inner session (empty when in sync).
    unique: Vec<u64>,
    /// `Kᵢ = Sᵢ ∩ C`, the membership-round candidates.
    kept: Vec<u64>,
    /// `Kᵢ ∖ ∩` decoded in the membership round.
    dropped: Vec<u64>,
    intersection: Vec<u64>,
    kind: ProtocolKind,
    attempts: u32,
    tracer: Tracer,
}

impl Party {
    /// A spoke holding `set`, claiming `id` (1-based) in a round of `count` parties.
    pub fn new(cfg: &SetxConfig, set: Vec<u64>, id: u32, count: u32) -> Result<Party, MultiError> {
        if !(2..=MAX_PARTIES).contains(&count) {
            return Err(MultiError::Config(format!(
                "party count {count} outside [2, {MAX_PARTIES}]"
            )));
        }
        if id == 0 || id >= count {
            return Err(MultiError::Config(format!(
                "party id {id} outside [1, {}]",
                count - 1
            )));
        }
        let mut sorted = set.clone();
        sorted.sort_unstable();
        Ok(Party {
            cfg: *cfg,
            set: Arc::new(set),
            sorted,
            id,
            count,
            phase: PartyPhase::AwaitCoordHello,
            comm: CommLog::new(),
            ests: None,
            nego: None,
            cache: DecoderCache::new(),
            enc: EncodeConfig { threads: cfg.encode_threads },
            unique: Vec::new(),
            kept: Vec::new(),
            dropped: Vec::new(),
            intersection: Vec::new(),
            kind: ProtocolKind::Uni,
            attempts: 0,
            tracer: if cfg.tracing { Tracer::new() } else { Tracer::disabled() },
        })
    }

    pub fn phase_name(&self) -> &'static str {
        party_phase_name(&self.phase)
    }

    /// Opening frames (the party hello).
    pub fn start(&mut self) -> Vec<Msg> {
        self.tracer.open(SpanKind::Handshake);
        self.tracer.open(SpanKind::Estimate);
        let (mut hello, ests) = build_est_hello(&self.cfg, &self.set);
        self.tracer.close(SpanKind::Estimate);
        if let Msg::EstHello { party, .. } = &mut hello {
            *party = Some((self.id, self.count));
        }
        self.ests = ests;
        self.record_sent(&hello);
        self.phase = PartyPhase::AwaitCoordHello;
        vec![hello]
    }

    /// Absorb one coordinator frame.
    pub fn on_msg(&mut self, msg: &Msg) -> Step {
        match (std::mem::replace(&mut self.phase, PartyPhase::Done), msg) {
            (
                PartyPhase::AwaitCoordHello,
                Msg::EstHello {
                    config_fingerprint,
                    set_len,
                    explicit_d,
                    strata,
                    minhash,
                    namespace,
                    party,
                    codec,
                },
            ) => {
                self.record_recv(msg);
                let ours = self.cfg.fingerprint();
                if *config_fingerprint != ours {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::ConfigMismatch { ours, theirs: *config_fingerprint },
                    );
                }
                if *party != Some((0, self.count)) {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("coordinator hello party mismatch"),
                    );
                }
                if *namespace != self.cfg.namespace() {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("coordinator hello namespace mismatch"),
                    );
                }
                let Ok(peer_len) = usize::try_from(*set_len) else {
                    return Step::Fatal(Vec::new(), SetxError::MalformedFrame("set_len"));
                };
                let ests = self.ests.take();
                self.tracer.open(SpanKind::Estimate);
                let nego = negotiate(
                    &self.cfg,
                    true,
                    self.set.len(),
                    ests.as_ref(),
                    peer_len,
                    *explicit_d,
                    strata.as_deref(),
                    minhash.as_deref(),
                    *codec,
                );
                self.tracer.close(SpanKind::Estimate);
                match nego {
                    Ok(nego) => {
                        self.tracer.close(SpanKind::Handshake);
                        self.nego = Some(nego);
                        self.phase = PartyPhase::AwaitCollectHello;
                        Step::Continue
                    }
                    Err(e) => Step::Fatal(Vec::new(), e),
                }
            }
            (
                PartyPhase::AwaitCollectHello,
                Msg::Hello {
                    l,
                    m,
                    seed,
                    universe_bits,
                    est_initiator_unique,
                    est_responder_unique,
                    set_len: _,
                    namespace,
                },
            ) => {
                self.record_recv(msg);
                if *namespace != self.cfg.namespace() {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("collect hello namespace mismatch"),
                    );
                }
                if !wire_geometry_ok(*l, *m, *seed) || *universe_bits != self.cfg.universe_bits {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("collect hello geometry"),
                    );
                }
                let (Ok(est_a), Ok(est_b)) = (
                    usize::try_from(*est_initiator_unique),
                    usize::try_from(*est_responder_unique),
                ) else {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("collect hello estimates"),
                    );
                };
                let params = CsParams {
                    l: *l,
                    m: *m,
                    seed: *seed,
                    universe_bits: *universe_bits,
                    est_a_unique: est_a,
                    est_b_unique: est_b,
                };
                let wire_codec = self.nego.is_some_and(|n| n.codec);
                self.tracer.open(SpanKind::SketchEncode);
                let (sketch, _) =
                    uni::alice_encode_with(&self.set, &params, self.enc, None, wire_codec);
                self.tracer.close(SpanKind::SketchEncode);
                self.record_sent(&sketch);
                self.phase = PartyPhase::AwaitDirective { params };
                Step::Send(vec![sketch])
            }
            (
                PartyPhase::AwaitDirective { params },
                Msg::AggSketch { parties: _, l, m, seed, digest, directive, counts, codec: _ },
            ) => {
                self.record_recv(msg);
                if (*l, *m, *seed) != (params.l, params.m, params.seed) {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("aggregate geometry skew"),
                    );
                }
                if let Some(c) = counts {
                    // The aggregate payload is telemetry, but when present it must at
                    // least be self-consistent with its own digest.
                    let folded: Vec<i64> = c.iter().map(|&v| v as i64).collect();
                    if agg_digest(&folded, *seed) != *digest {
                        return Step::Fatal(
                            Vec::new(),
                            SetxError::MalformedFrame("aggregate digest mismatch"),
                        );
                    }
                }
                if *directive == DIRECTIVE_IN_SYNC {
                    self.unique = Vec::new();
                    self.kept = self.sorted.clone();
                    self.phase = PartyPhase::AwaitMembership;
                    return Step::Continue;
                }
                let nego = self.nego.expect("negotiated before directive");
                let mut ep =
                    Endpoint::new_owned_negotiated(self.cfg, self.set.clone(), true, nego);
                ep.set_encode(self.enc);
                ep.set_cache(std::mem::take(&mut self.cache));
                let msgs = ep.start();
                self.phase = PartyPhase::Session(Box::new(ep));
                Step::Send(msgs)
            }
            (PartyPhase::Session(mut ep), _) => match ep.on_msg(msg) {
                Step::Send(msgs) => {
                    self.phase = PartyPhase::Session(ep);
                    Step::Send(msgs)
                }
                Step::Continue => {
                    self.phase = PartyPhase::Session(ep);
                    Step::Continue
                }
                Step::Finish(msgs, report) => {
                    self.cache = ep.take_cache();
                    self.comm.extend(&report.comm);
                    self.tracer.absorb(&report.trace);
                    self.kind = report.kind;
                    self.attempts = report.attempts;
                    self.unique = report.local_unique;
                    let mine: HashSet<u64> = self.unique.iter().copied().collect();
                    self.kept =
                        self.sorted.iter().copied().filter(|x| !mine.contains(x)).collect();
                    self.phase = PartyPhase::AwaitMembership;
                    Step::Send(msgs)
                }
                Step::Fatal(msgs, err) => Step::Fatal(msgs, err),
            },
            (PartyPhase::AwaitMembership, Msg::Confirm { ok, reason, attempt }) => {
                self.record_recv(msg);
                if !*ok {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::Decode {
                            failure: reason_to_failure(*reason),
                            attempts: attempt + 1,
                        },
                    );
                }
                // Bare confirm: our pairwise-common set is exactly the intersection.
                self.intersection = self.kept.clone();
                self.phase = PartyPhase::AwaitFinal;
                let ack = Msg::Confirm { ok: true, reason: REASON_OK, attempt: *attempt };
                self.record_sent(&ack);
                Step::Send(vec![ack])
            }
            (
                PartyPhase::AwaitMembership,
                Msg::MultiResidue {
                    party,
                    attempt,
                    l,
                    m,
                    seed,
                    universe_bits,
                    est_drop,
                    sketch,
                    codec: _,
                },
            ) => {
                self.record_recv(msg);
                if *party != self.id {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("membership frame for another party"),
                    );
                }
                if !wire_geometry_ok(*l, *m, *seed)
                    || *est_drop > self.kept.len() as u64
                    || *universe_bits != self.cfg.universe_bits
                {
                    return Step::Fatal(
                        Vec::new(),
                        SetxError::MalformedFrame("membership geometry"),
                    );
                }
                let params = CsParams {
                    l: *l,
                    m: *m,
                    seed: *seed,
                    universe_bits: *universe_bits,
                    est_a_unique: 0,
                    est_b_unique: *est_drop as usize,
                };
                self.attempts = self.attempts.max(attempt + 1);
                match uni::bob_decode_with(
                    &Msg::Sketch { sketch: sketch.clone(), codec: false },
                    &self.kept,
                    &params,
                    &mut self.cache,
                    None,
                    self.enc,
                ) {
                    Ok((dropped, _)) => {
                        let gone: HashSet<u64> = dropped.iter().copied().collect();
                        self.intersection =
                            self.kept.iter().copied().filter(|x| !gone.contains(x)).collect();
                        self.dropped = dropped;
                        self.phase = PartyPhase::AwaitFinal;
                        let ack = Msg::Confirm { ok: true, reason: REASON_OK, attempt: *attempt };
                        self.record_sent(&ack);
                        Step::Send(vec![ack])
                    }
                    Err(uni::UniError::Decode(failure)) => {
                        // This rung failed: report why and wait for the escalated
                        // re-sketch (or the coordinator's teardown).
                        let nack = Msg::Confirm {
                            ok: false,
                            reason: failure_to_reason(failure),
                            attempt: *attempt,
                        };
                        self.record_sent(&nack);
                        self.phase = PartyPhase::AwaitMembership;
                        Step::Send(vec![nack])
                    }
                    Err(uni::UniError::Frame(what)) => {
                        Step::Fatal(Vec::new(), SetxError::MalformedFrame(what))
                    }
                }
            }
            (PartyPhase::AwaitCoordHello, Msg::Busy { retry_after_ms, namespace }) => {
                // Admission rejection (daemon over quota, tenant not a coordinator, or
                // a duplicate/mid-round join): surface the typed error so the caller
                // can back off and retry, exactly as a two-party client would.
                self.record_recv(msg);
                Step::Fatal(
                    Vec::new(),
                    SetxError::ServerBusy {
                        retry_after_ms: *retry_after_ms,
                        namespace: *namespace,
                    },
                )
            }
            (PartyPhase::AwaitFinal, Msg::Confirm { ok: true, .. }) => {
                self.record_recv(msg);
                let mut local_unique: Vec<u64> =
                    self.unique.iter().chain(self.dropped.iter()).copied().collect();
                local_unique.sort_unstable();
                let report = SetxReport {
                    intersection: std::mem::take(&mut self.intersection),
                    local_unique,
                    kind: self.kind,
                    converged: true,
                    attempts: self.attempts.max(1),
                    rounds: self.comm.payload_frames(),
                    retries: 0,
                    retry_bytes: 0,
                    comm: std::mem::take(&mut self.comm),
                    local_is_alice: true,
                    trace: self.tracer.take(),
                };
                Step::Finish(Vec::new(), Box::new(report))
            }
            (_, _) => {
                self.record_recv(msg);
                Step::Fatal(
                    Vec::new(),
                    SetxError::MalformedFrame("frame out of phase for this party"),
                )
            }
        }
    }

    /// Drive this spoke over a transport to completion (the multi-party sibling of
    /// [`Setx::run`]).
    pub fn run<T: super::transport::Transport>(
        &mut self,
        transport: &mut T,
    ) -> Result<SetxReport, SetxError> {
        for msg in self.start() {
            transport.send(&msg)?;
        }
        loop {
            let Some(msg) = transport.recv()? else {
                return Err(SetxError::PeerClosed { during: self.phase_name() });
            };
            match self.on_msg(&msg) {
                Step::Send(msgs) => {
                    for m in msgs {
                        transport.send(&m)?;
                    }
                }
                Step::Continue => {}
                Step::Finish(msgs, report) => {
                    for m in msgs {
                        transport.send(&m)?;
                    }
                    return Ok(*report);
                }
                Step::Fatal(msgs, err) => {
                    for m in msgs {
                        let _ = transport.send(&m);
                    }
                    return Err(err);
                }
            }
        }
    }

    fn record_sent(&mut self, msg: &Msg) {
        log_frame(&mut self.comm, true, msg);
        self.mark_frame(msg);
    }

    fn record_recv(&mut self, msg: &Msg) {
        log_frame(&mut self.comm, false, msg);
        self.mark_frame(msg);
    }

    /// One [`SpanKind::Round`] marker per payload frame this spoke logs directly, so
    /// the timeline's marker count matches [`CommLog::payload_frames`] (frames logged
    /// by the inner pairwise endpoint carry their own markers, absorbed at Finish).
    fn mark_frame(&mut self, msg: &Msg) {
        let phase = frame_phase(msg);
        if phase.is_payload() {
            self.tracer.instant(SpanKind::Round);
        } else if phase == crate::metrics::Phase::Confirm {
            self.tracer.instant(SpanKind::Confirm);
        }
    }
}

/// A configured in-process multi-party round: the builder's set is party 0 (the
/// coordinator), `sets[1..]` the spokes. Obtain via [`super::SetxBuilder::parties`].
pub struct MultiSetx {
    cfg: SetxConfig,
    sets: Vec<Arc<Vec<u64>>>,
}

impl MultiSetx {
    pub(crate) fn new(cfg: SetxConfig, sets: Vec<Arc<Vec<u64>>>) -> Result<MultiSetx, MultiError> {
        if sets.len() < 2 {
            return Err(MultiError::Config(format!(
                "multi-party round needs ≥ 2 sets, got {}",
                sets.len()
            )));
        }
        if sets.len() as u64 > MAX_PARTIES as u64 {
            return Err(MultiError::Config(format!(
                "party count {} above {MAX_PARTIES}",
                sets.len()
            )));
        }
        Ok(MultiSetx { cfg, sets })
    }

    /// Run the round deterministically in-process (no threads — the multi-party sibling
    /// of [`Setx::run_pair`]) and return the coordinator's report.
    pub fn run(&self) -> Result<MultiReport, MultiError> {
        self.run_detailed().map(|(report, _)| report)
    }

    /// [`MultiSetx::run`] also returning every spoke's own [`SetxReport`] (party-id
    /// order) — what the verifying harnesses assert against.
    pub fn run_detailed(&self) -> Result<(MultiReport, Vec<SetxReport>), MultiError> {
        let count = self.sets.len() as u32;
        let mut coord = MultiCoordinator::new(&self.cfg, self.sets[0].clone(), count)?;
        let mut parties: Vec<Party> = (1..count)
            .map(|id| {
                Party::new(&self.cfg, (*self.sets[id as usize]).clone(), id, count)
            })
            .collect::<Result<_, _>>()?;
        // Per-spoke frame queues, coordinator ↔ party i+1.
        let mut to_coord: Vec<std::collections::VecDeque<Msg>> =
            (1..count).map(|_| std::collections::VecDeque::new()).collect();
        let mut to_party: Vec<std::collections::VecDeque<Msg>> =
            (1..count).map(|_| std::collections::VecDeque::new()).collect();
        let mut reports: Vec<Option<SetxReport>> = (1..count).map(|_| None).collect();
        let mut failed: Vec<Option<SetxError>> = (1..count).map(|_| None).collect();
        for (i, party) in parties.iter_mut().enumerate() {
            to_coord[i].extend(party.start());
        }
        // Joins route through `route_hello` exactly as a server connection would.
        for q in &mut to_coord {
            let hello = q.pop_front().expect("party start sends its hello");
            let (_, frames) = coord.route_hello(&hello)?;
            for (p, m) in frames {
                to_party[(p - 1) as usize].push_back(m);
            }
        }
        loop {
            let mut progressed = false;
            for i in 0..to_coord.len() {
                let party_id = (i + 1) as u32;
                while let Some(msg) = to_coord[i].pop_front() {
                    progressed = true;
                    for (p, m) in coord.on_msg(party_id, &msg) {
                        to_party[(p - 1) as usize].push_back(m);
                    }
                }
                if reports[i].is_some() || failed[i].is_some() {
                    to_party[i].clear();
                    continue;
                }
                while let Some(msg) = to_party[i].pop_front() {
                    progressed = true;
                    match parties[i].on_msg(&msg) {
                        Step::Send(msgs) => to_coord[i].extend(msgs),
                        Step::Continue => {}
                        Step::Finish(msgs, report) => {
                            to_coord[i].extend(msgs);
                            reports[i] = Some(*report);
                        }
                        Step::Fatal(msgs, err) => {
                            to_coord[i].extend(msgs);
                            failed[i] = Some(err);
                        }
                    }
                }
            }
            let all_parties_done =
                (0..reports.len()).all(|i| reports[i].is_some() || failed[i].is_some());
            let queues_empty = to_coord.iter().all(|q| q.is_empty())
                && to_party.iter().all(|q| q.is_empty());
            if coord.is_done() && all_parties_done && queues_empty {
                break;
            }
            if !progressed {
                // Both sides idle with frames owed: a failed spoke the coordinator still
                // awaits is dropped (the in-process analogue of the deadline); anything
                // else is a drive bug.
                let mut dropped_any = false;
                for i in 0..reports.len() {
                    let party_id = (i + 1) as u32;
                    if failed[i].is_some() && coord.awaiting(party_id) {
                        for (p, m) in
                            coord.drop_party(party_id, MultiError::PartyTimeout { party: party_id })
                        {
                            to_party[(p - 1) as usize].push_back(m);
                        }
                        dropped_any = true;
                    }
                }
                if !dropped_any {
                    return Err(MultiError::Config(
                        "in-process multi-party drive stalled".into(),
                    ));
                }
            }
        }
        let report = coord.into_report();
        let mut spoke_reports = Vec::new();
        for (i, slot) in reports.into_iter().enumerate() {
            match slot {
                Some(r) => spoke_reports.push(r),
                None => {
                    let party = (i + 1) as u32;
                    let error = failed[i]
                        .take()
                        .unwrap_or(SetxError::PeerClosed { during: "multi-party round" });
                    return Err(MultiError::Party { party, error });
                }
            }
        }
        Ok((report, spoke_reports))
    }
}

impl super::SetxBuilder {
    /// Turn this builder into an in-process multi-party round: the builder's set is the
    /// coordinator (party 0), `others` the spokes. All config knobs set on the builder
    /// apply to every party (multi-party rounds require identical configs, exactly like
    /// two-party sessions).
    pub fn parties(self, others: &[Vec<u64>]) -> Result<MultiSetx, MultiError> {
        let setx = self.build().map_err(|e| MultiError::Config(e.to_string()))?;
        let mut sets = Vec::with_capacity(1 + others.len());
        sets.push(Arc::new(setx.set));
        sets.extend(others.iter().map(|s| Arc::new(s.clone())));
        MultiSetx::new(setx.cfg, sets)
    }
}

impl Setx {
    /// Compute `∩ᵢSᵢ` across N ≥ 2 sets in-process with default config: `sets[0]` is the
    /// coordinator, the rest are spokes. See [`MultiSetx`] for custom knobs.
    pub fn multi(sets: &[Vec<u64>]) -> Result<MultiReport, MultiError> {
        if sets.is_empty() {
            return Err(MultiError::Config("no sets".into()));
        }
        Setx::builder(&sets[0]).parties(&sets[1..])?.run()
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::data::synth;

    /// N sets sharing a common core of `common` ids plus `unique` per-party ids from
    /// disjoint tails, so the exact intersection is the core by construction.
    pub fn n_sets(n: usize, common: usize, unique: usize, seed: u64) -> Vec<Vec<u64>> {
        synth::overlap_n(n, common, unique, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::n_sets;
    use super::*;

    #[test]
    fn three_party_round_in_process() {
        let sets = n_sets(3, 600, 12, 42);
        let multi = Setx::builder(&sets[0]).parties(&sets[1..]).unwrap();
        let (report, spoke_reports) = multi.run_detailed().unwrap();
        let mut expect: Vec<u64> = sets[0]
            .iter()
            .copied()
            .filter(|x| sets[1..].iter().all(|s| s.contains(x)))
            .collect();
        expect.sort_unstable();
        assert_eq!(report.intersection, expect);
        assert_eq!(report.completed(), 2);
        for r in &spoke_reports {
            assert_eq!(r.intersection, expect);
        }
        // Per-party bytes sum to the coordinator total by construction — and each
        // spoke's own transcript agrees with the coordinator's view of it.
        let sum: usize = report.parties.iter().map(|p| p.total_bytes()).sum();
        assert_eq!(sum, report.total_bytes());
        for (p, r) in report.parties.iter().zip(&spoke_reports) {
            assert_eq!(p.comm.total_bytes(), r.total_bytes(), "party {}", p.party);
        }
    }

    #[test]
    fn identical_sets_take_the_synced_fast_path() {
        let base: Vec<u64> = (1..400u64).map(|x| x * 3).collect();
        let sets = vec![base.clone(), base.clone(), base.clone(), base.clone()];
        let report = Setx::multi(&sets).unwrap();
        let mut expect = base;
        expect.sort_unstable();
        assert_eq!(report.intersection, expect);
        for p in &report.parties {
            assert!(p.synced, "identical party {} must be in sync", p.party);
            assert_eq!(p.attempts, 0);
            assert!(p.error.is_none());
        }
    }

    #[test]
    fn duplicate_party_id_is_rejected_without_killing_the_round() {
        let sets = n_sets(3, 200, 5, 9);
        let cfg = *Setx::builder(&sets[0]).build().unwrap().config();
        let mut coord =
            MultiCoordinator::new(&cfg, Arc::new(sets[0].clone()), 3).unwrap();
        let mut p1 = Party::new(&cfg, sets[1].clone(), 1, 3).unwrap();
        let hello1 = p1.start().remove(0);
        coord.route_hello(&hello1).unwrap();
        // A second connection claiming id 1: rejected, round intact.
        let mut imp = Party::new(&cfg, sets[2].clone(), 1, 3).unwrap();
        let imp_hello = imp.start().remove(0);
        assert!(matches!(
            coord.route_hello(&imp_hello),
            Err(MultiError::DuplicateParty { party: 1 })
        ));
        assert!(coord.joined(1));
        assert!(!coord.is_done());
    }

    #[test]
    fn misconfigured_party_counts_rejected() {
        let set: Vec<u64> = (0..50).collect();
        assert!(matches!(
            Setx::builder(&set).parties(&[]),
            Err(MultiError::Config(_))
        ));
        let cfg = *Setx::builder(&set).build().unwrap().config();
        assert!(Party::new(&cfg, set.clone(), 0, 3).is_err());
        assert!(Party::new(&cfg, set.clone(), 3, 3).is_err());
        assert!(MultiCoordinator::new(&cfg, Arc::new(set.clone()), 1).is_err());
        // A join whose count disagrees with the coordinator's roster size.
        let mut coord = MultiCoordinator::new(&cfg, Arc::new(set.clone()), 3).unwrap();
        let mut p = Party::new(&cfg, set.clone(), 1, 4).unwrap();
        let hello = p.start().remove(0);
        assert!(matches!(
            coord.route_hello(&hello),
            Err(MultiError::Party { party: 1, .. })
        ));
    }

    #[test]
    fn deadline_join_runs_with_partial_roster() {
        let sets = n_sets(4, 300, 8, 77);
        let cfg = *Setx::builder(&sets[0]).build().unwrap().config();
        let mut coord = MultiCoordinator::new(&cfg, Arc::new(sets[0].clone()), 4).unwrap();
        let mut p1 = Party::new(&cfg, sets[1].clone(), 1, 4).unwrap();
        let hello = p1.start().remove(0);
        let (_, frames) = coord.route_hello(&hello).unwrap();
        // Roster incomplete: only the coordinator's hello so far, no collect broadcast.
        assert_eq!(frames.len(), 1);
        assert!(!coord.awaiting(1));
        // Parties 2 and 3 never dial in; the deadline closes the roster.
        let frames = coord.deadline_join();
        assert!(
            frames.iter().any(|(p, m)| *p == 1 && matches!(m, Msg::Hello { .. })),
            "collect hello must go out to the joined spoke"
        );
        assert!(coord.awaiting(1));
        assert!(!coord.joined(2));
    }

    #[test]
    fn transient_classification_mirrors_the_two_party_contract() {
        // Dropped/stalled spokes and full rounds retry; identity and config
        // faults do not; Party delegates to the inner SetxError verdict.
        assert!(MultiError::PartyTimeout { party: 2 }.is_transient());
        assert!(MultiError::RoundInProgress.is_transient());
        assert!(!MultiError::Config("bad".to_string()).is_transient());
        assert!(!MultiError::DuplicateParty { party: 1 }.is_transient());
        let io = SetxError::Io(std::io::Error::new(
            std::io::ErrorKind::ConnectionReset,
            "fault: connection dropped",
        ));
        assert!(MultiError::Party { party: 3, error: io }.is_transient());
        let fatal = SetxError::MalformedFrame("fault: flipped frame bytes");
        assert!(!MultiError::Party { party: 3, error: fatal }.is_transient());
    }
}
