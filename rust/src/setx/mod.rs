//! **The front door.** One builder-first endpoint API over every SetX transport.
//!
//! The paper's pitch is that SetX should be a drop-in primitive, yet a protocol engine
//! alone still demands that callers pre-compute [`crate::protocol::CsParams`] — including
//! the very `d = |AΔB|` the protocol exists to discover — and pick among transport-shaped
//! entry points with divergent outcome and error types. This module collapses all of that
//! into one surface:
//!
//! ```
//! use commonsense::setx::Setx;
//! use commonsense::data::synth;
//!
//! let (a, b) = synth::overlap_pair(2_000, 40, 60, 7);
//! let alice = Setx::builder(&a).build().unwrap();
//! let bob = Setx::builder(&b).build().unwrap();
//! // In-process run; `Setx::run` drives the same endpoint over any `Transport`.
//! let (ra, rb) = alice.run_pair(&bob).unwrap();
//! assert_eq!(ra.intersection, rb.intersection);
//! assert_eq!(ra.local_unique, synth::difference(&a, &b));
//! ```
//!
//! * **No caller-supplied `d`** — by default ([`DiffSize::Estimated`]) the endpoints run a
//!   Strata + MinHash pre-round inside the handshake (`EstHello` frames) and negotiate
//!   the difference estimate, the initiator role, and (in [`Mode::Auto`]) whether the
//!   cheap unidirectional protocol applies.
//! * **One run surface** — `Setx::builder(set)…build()?.run(&mut transport)` works for the
//!   in-memory channel ([`transport::mem_pair`]), TCP ([`transport::TcpTransport`]), and —
//!   via the partitioned pool driver ([`parallel::run_partitioned`]) — the §7.3 scale-out.
//! * **One report, one error** — every path returns a [`SetxReport`] (intersection,
//!   rounds, attempts, per-phase/per-direction byte breakdown from the
//!   [`crate::metrics::CommLog`]) or a typed [`SetxError`].
//! * **Self-healing** — on a residual-decode failure the endpoints exchange a `Confirm`
//!   verdict and the initiator retries *on the same connection* with the sketch length
//!   escalated along a calibrated safety ladder ([`SetxConfig::ladder_factor`]), instead
//!   of failing opaquely.

pub(crate) mod endpoint;
pub mod multi;
pub mod parallel;
pub mod retry;
pub mod transport;

pub use retry::RetryPolicy;

use crate::decoder::DecoderCache;
use crate::hash::hash_u64;
use crate::metrics::{CommLog, Phase};
use crate::obs::{PhaseDurations, SessionTrace};
use crate::protocol::bidi::BidiOptions;
use crate::protocol::session::SessionError;
use endpoint::{Endpoint, Step};
use std::sync::Mutex;
use transport::Transport;

/// Which protocol family to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// §3 one-message unidirectional SetX. Requires the initiator's set to be (nearly) a
    /// subset of the responder's; otherwise the decode fails and the ladder exhausts.
    Uni,
    /// §5 bidirectional ping-pong (the general case).
    Bidi,
    /// Decide from the handshake estimators: unidirectional when the smaller side shows
    /// zero uniques (the directional Strata signal), bidirectional otherwise — and fall
    /// back to bidirectional on any retry.
    Auto,
}

/// Where `d = |AΔB|` comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiffSize {
    /// Caller-supplied symmetric-difference cardinality (both endpoints must configure
    /// the same value — it is part of the config fingerprint).
    Explicit(usize),
    /// Estimate `d` in the handshake via Strata + MinHash (§7.1) — the default; callers
    /// never supply `d`.
    Estimated,
}

/// Which protocol family a run actually used (reported per attempt; `Mode::Auto` resolves
/// to one of these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtocolKind {
    Uni,
    Bidi,
}

// The engine-level failure diagnosis, re-exported as part of the facade surface (the
// ladder and [`SetxError::Decode`] speak the same vocabulary as [`crate::protocol::uni`]).
pub use crate::protocol::DecodeFailure;

/// The one typed error surface of the facade. Absorbs the engine's
/// [`SessionError`], transport I/O errors, and decode failures (which carry *why*).
#[derive(Debug)]
pub enum SetxError {
    /// Builder validation rejected the declarative config.
    Config(String),
    /// The peer's declarative config does not match ours (fingerprints differ).
    ConfigMismatch { ours: u64, theirs: u64 },
    /// Transport-level I/O failure.
    Io(std::io::Error),
    /// The peer closed the connection before the protocol completed.
    PeerClosed { during: &'static str },
    /// A frame failed to parse or carried an invalid/missing field.
    MalformedFrame(&'static str),
    /// A structurally valid frame arrived out of phase (terminal, like the engine's).
    Protocol(SessionError),
    /// Every attempt of the escalation ladder failed; `failure` is the last attempt's
    /// reason and `attempts` how many were tried.
    Decode { failure: DecodeFailure, attempts: u32 },
    /// The server rejected the connection at admission (its global
    /// `max_inflight_sessions` cap, or the per-tenant quota of `namespace`): a
    /// [`crate::protocol::wire::Msg::Busy`] frame arrived instead of the handshake.
    /// Retry after roughly `retry_after_ms` (0 = no server hint) plus client-side jitter.
    ServerBusy {
        retry_after_ms: u32,
        /// Tenant whose quota rejected us (0 = the global cap / default tenant).
        namespace: u32,
    },
}

impl std::fmt::Display for SetxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SetxError::Config(why) => write!(f, "invalid config: {why}"),
            SetxError::ConfigMismatch { ours, theirs } => {
                write!(f, "peer config mismatch (ours {ours:#x}, theirs {theirs:#x})")
            }
            SetxError::Io(e) => write!(f, "transport i/o: {e}"),
            SetxError::PeerClosed { during } => write!(f, "peer closed during {during}"),
            SetxError::MalformedFrame(what) => write!(f, "malformed frame: {what}"),
            SetxError::Protocol(e) => write!(f, "protocol violation: {e}"),
            SetxError::Decode { failure, attempts } => {
                write!(f, "{} after {attempts} attempt(s)", failure.name())
            }
            SetxError::ServerBusy { retry_after_ms, namespace } => {
                write!(
                    f,
                    "server at admission capacity for tenant {namespace} \
                     (retry after ~{retry_after_ms} ms)"
                )
            }
        }
    }
}

impl SetxError {
    /// Whether a retry on a **fresh connection** can plausibly succeed — the
    /// classification contract [`Setx::run_with_retry`] and the server loadgen
    /// act on. Transport I/O failures, admission pushback
    /// ([`SetxError::ServerBusy`]), and peer closes are transient (the link or
    /// the moment was bad, not the configuration); everything else — config
    /// mismatches, malformed frames, protocol violations, decode exhaustion —
    /// reproduces on a clean link, so retrying it only burns the budget.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            SetxError::Io(_) | SetxError::ServerBusy { .. } | SetxError::PeerClosed { .. }
        )
    }
}

impl std::error::Error for SetxError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SetxError::Io(e) => Some(e),
            SetxError::Protocol(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SetxError {
    fn from(e: std::io::Error) -> Self {
        SetxError::Io(e)
    }
}

impl From<SessionError> for SetxError {
    fn from(e: SessionError) -> Self {
        SetxError::Protocol(e)
    }
}

impl From<crate::protocol::uni::UniError> for SetxError {
    fn from(e: crate::protocol::uni::UniError) -> Self {
        use crate::protocol::uni::UniError;
        match e {
            UniError::Frame(what) => SetxError::MalformedFrame(what),
            UniError::Decode(failure) => SetxError::Decode { failure, attempts: 1 },
        }
    }
}

/// The validated declarative config a [`Setx`] endpoint runs under. Both endpoints of a
/// session must hold identical configs — [`SetxConfig::fingerprint`] travels in the
/// opening `EstHello` frame and a mismatch aborts before any protocol work.
#[derive(Clone, Copy, Debug)]
pub struct SetxConfig {
    pub mode: Mode,
    pub diff: DiffSize,
    /// Extra multiplier on the calibrated sketch-length safety factor (1.0 = calibrated).
    pub safety: f64,
    /// Shared seed: CS matrices, handshake estimators, and signatures all derive from it.
    pub seed: u64,
    /// Nominal universe bit-width for communication accounting.
    pub universe_bits: u32,
    /// Ladder depth: how many decode attempts (with escalating `l`) before giving up.
    pub max_attempts: u32,
    /// Encode-side worker threads for this endpoint's own-set sketch encodes (`0` = auto,
    /// mirroring [`crate::decoder::DecoderConfig::build_threads`]; clamped to 64; small
    /// sets always encode serially). **Deliberately not fingerprinted**: the parallel
    /// encode is bit-identical to the serial one, so peers with different thread counts
    /// interoperate — this is a local performance knob, not protocol state.
    pub encode_threads: usize,
    /// Engine tunables (round budget, SMF fpr, …) — advanced; defaults match the paper.
    /// `engine.namespace` carries the tenant namespace (see [`SetxConfig::namespace`]).
    pub engine: BidiOptions,
    /// Record a [`SessionTrace`] timeline (default on; see [`crate::obs`]). Off, the
    /// tracer is fully disabled — no timestamps taken, nothing allocated — which is the
    /// bench-ablation path. **Deliberately not fingerprinted**: tracing is pure local
    /// observation with zero wire impact, so traced and untraced peers interoperate.
    pub tracing: bool,
    /// Reconnect policy for [`Setx::run_with_retry`] (see [`RetryPolicy`]).
    /// **Deliberately not fingerprinted**: when (and whether) a client
    /// reconnects is a local decision with no wire impact, so peers with
    /// different policies interoperate.
    pub retry: RetryPolicy,
}

impl SetxConfig {
    /// The escalation ladder: attempt `k` multiplies the calibrated safety factor by
    /// `1.6^k` (≈ +60% sketch rows per retry; three rungs span a 2.5× misestimate of `d`,
    /// beyond the Strata estimator's observed error band).
    pub fn ladder_factor(attempt: u32) -> f64 {
        1.6f64.powi(attempt.min(8) as i32)
    }

    /// The tenant namespace this endpoint reconciles against (0 = the default tenant; a
    /// multi-tenant [`crate::server::SetxServer`] routes the session to the matching
    /// resident host set). **Deliberately not fingerprinted** — it selects *which* set a
    /// server answers with, it does not change the protocol, so clients of different
    /// tenants share one config fingerprint.
    pub fn namespace(&self) -> u32 {
        self.engine.namespace
    }

    /// Order-sensitive hash of every semantic field. Equal configs ⇒ equal fingerprints;
    /// endpoints exchange this in `EstHello` and refuse mismatched peers. The tenant
    /// [`SetxConfig::namespace`] is intentionally excluded (routing, not protocol).
    pub fn fingerprint(&self) -> u64 {
        let diff_tag = match self.diff {
            DiffSize::Explicit(d) => [1u64, d as u64],
            DiffSize::Estimated => [2u64, 0],
        };
        let fields = [
            0x5e7c_0de5_0002u64, // fingerprint format version
            match self.mode {
                Mode::Uni => 1,
                Mode::Bidi => 2,
                Mode::Auto => 3,
            },
            diff_tag[0],
            diff_tag[1],
            self.safety.to_bits(),
            self.seed,
            self.universe_bits as u64,
            self.max_attempts as u64,
            self.engine.max_rounds as u64,
            self.engine.confident_round as u64,
            self.engine.smf_fpr.to_bits(),
            self.engine.ssmp_fallback as u64,
            self.engine.sig_seed,
        ];
        let mut h = 0xC033_0A5E_u64;
        for v in fields {
            h = hash_u64(h ^ v, 0x5e7c_0de5);
        }
        h
    }
}

/// Builder for a [`Setx`] endpoint. Obtain via [`Setx::builder`]; every knob has a
/// paper-calibrated default, so `Setx::builder(&set).build()` is a complete endpoint.
#[derive(Clone, Debug)]
pub struct SetxBuilder {
    set: Vec<u64>,
    cfg: SetxConfig,
}

impl SetxBuilder {
    /// Protocol family ([`Mode::Auto`] by default).
    pub fn mode(mut self, mode: Mode) -> Self {
        self.cfg.mode = mode;
        self
    }

    /// Where `d = |AΔB|` comes from ([`DiffSize::Estimated`] by default).
    pub fn diff_size(mut self, diff: DiffSize) -> Self {
        self.cfg.diff = diff;
        self
    }

    /// Extra safety multiplier on the calibrated sketch length (default 1.0). Values
    /// below 1.0 under-provision the first attempt and lean on the escalation ladder.
    pub fn safety(mut self, safety: f64) -> Self {
        self.cfg.safety = safety;
        self
    }

    /// Shared protocol seed (matrices, estimators, signatures). Default `0xC0FFEE`.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Nominal universe bit-width for accounting (default 64).
    pub fn universe_bits(mut self, bits: u32) -> Self {
        self.cfg.universe_bits = bits;
        self
    }

    /// Ladder depth: decode attempts before giving up (default 3).
    pub fn max_attempts(mut self, attempts: u32) -> Self {
        self.cfg.max_attempts = attempts;
        self
    }

    /// Encode-side worker threads for this endpoint's sketch encodes (default `0` =
    /// auto; `1` = serial). Local performance knob — not part of the config fingerprint,
    /// so the peer need not match it.
    pub fn encode_threads(mut self, threads: usize) -> Self {
        self.cfg.encode_threads = threads;
        self
    }

    /// Advanced engine tunables (round budget, SMF fpr, confident round, …). Note this
    /// replaces the whole options struct, including any [`SetxBuilder::namespace`] set
    /// earlier — set the namespace after (or via `opts.namespace`) when combining both.
    pub fn engine_options(mut self, opts: BidiOptions) -> Self {
        self.cfg.engine = opts;
        self
    }

    /// Tenant namespace to reconcile against (default 0 = the default tenant, which is
    /// also byte-identical on the wire to the pre-namespace frame format). Local routing
    /// knob — not part of the config fingerprint, so clients of different tenants still
    /// fingerprint-match the server.
    pub fn namespace(mut self, namespace: u32) -> Self {
        self.cfg.engine.namespace = namespace;
        self
    }

    /// Record a [`SessionTrace`] timeline for the run (default on; see
    /// [`SetxConfig::tracing`]). Turn off for the zero-overhead ablation — the report's
    /// [`SetxReport::trace`] comes back empty.
    pub fn tracing(mut self, on: bool) -> Self {
        self.cfg.tracing = on;
        self
    }

    /// Reconnect policy for [`Setx::run_with_retry`] (default
    /// [`RetryPolicy::default`]: 3 retries, 10 ms base, 2 s cap). Local
    /// recovery knob — not part of the config fingerprint, so the peer need
    /// not match it.
    pub fn retry_policy(mut self, policy: RetryPolicy) -> Self {
        self.cfg.retry = policy;
        self
    }

    /// Advertise the columnar wire codec (default on). The codec only engages when
    /// *both* endpoints advertise it in their `EstHello`; a mixed deployment negotiates
    /// down to the pre-codec frame format, byte-for-byte. Framing knob — deliberately
    /// not part of the config fingerprint, so codec-on and codec-off peers still
    /// handshake (and then talk codec-off).
    pub fn codec(mut self, on: bool) -> Self {
        self.cfg.engine.codec = on;
        self
    }

    /// Validate the config into a runnable endpoint.
    pub fn build(self) -> Result<Setx, SetxError> {
        let cfg = &self.cfg;
        if !(0.2..=8.0).contains(&cfg.safety) || !cfg.safety.is_finite() {
            return Err(SetxError::Config(format!(
                "safety {} outside [0.2, 8.0]",
                cfg.safety
            )));
        }
        if !(1..=8).contains(&cfg.max_attempts) {
            return Err(SetxError::Config(format!(
                "max_attempts {} outside [1, 8]",
                cfg.max_attempts
            )));
        }
        if !(8..=1024).contains(&cfg.universe_bits) {
            return Err(SetxError::Config(format!(
                "universe_bits {} outside [8, 1024]",
                cfg.universe_bits
            )));
        }
        if let DiffSize::Explicit(d) = cfg.diff {
            if d > 1 << 40 {
                return Err(SetxError::Config(format!("explicit d {d} implausibly large")));
            }
        }
        if cfg.engine.max_rounds == 0 || cfg.engine.max_rounds > 10_000 {
            return Err(SetxError::Config(format!(
                "engine max_rounds {} outside [1, 10000]",
                cfg.engine.max_rounds
            )));
        }
        if !(cfg.engine.smf_fpr > 0.0 && cfg.engine.smf_fpr <= 1.0) {
            return Err(SetxError::Config(format!(
                "engine smf_fpr {} outside (0, 1]",
                cfg.engine.smf_fpr
            )));
        }
        Ok(Setx { cfg: self.cfg, set: self.set, cache: Mutex::new(DecoderCache::new()) })
    }
}

/// A configured SetX endpoint: one local set plus a validated [`SetxConfig`]. Run it over
/// any [`Transport`]; the peer runs its own `Setx` (same config, its set) over the other
/// end.
pub struct Setx {
    pub(crate) cfg: SetxConfig,
    pub(crate) set: Vec<u64>,
    /// Decoder-reuse slot persisted across conversations of this endpoint: a steady-state
    /// re-sync (same set, same negotiated geometry — e.g. a server answering many clients
    /// in sequence, or periodic delta-syncs against the same peer) skips the dominant
    /// per-session cost, decoder construction, via [`crate::decoder::DecoderCache`].
    /// Interior-mutable so `run(&self, ..)` stays shared; never held across a blocking
    /// transport call.
    cache: Mutex<DecoderCache>,
}

impl Clone for Setx {
    fn clone(&self) -> Self {
        // The reuse cache is per-handle runtime state, not configuration: clones start
        // with an empty slot (a decoder is not Clone, and sharing one would serialize
        // the clones on a lock).
        Setx { cfg: self.cfg, set: self.set.clone(), cache: Mutex::new(DecoderCache::new()) }
    }
}

impl std::fmt::Debug for Setx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Setx")
            .field("cfg", &self.cfg)
            .field("set_len", &self.set.len())
            .finish_non_exhaustive()
    }
}

impl Setx {
    /// Start building an endpoint holding `set`.
    pub fn builder(set: &[u64]) -> SetxBuilder {
        SetxBuilder {
            set: set.to_vec(),
            cfg: SetxConfig {
                mode: Mode::Auto,
                diff: DiffSize::Estimated,
                safety: 1.0,
                seed: 0xC0FFEE,
                universe_bits: 64,
                max_attempts: 3,
                encode_threads: 0,
                engine: BidiOptions::default(),
                tracing: true,
                retry: RetryPolicy::default(),
            },
        }
    }

    pub fn config(&self) -> &SetxConfig {
        &self.cfg
    }

    pub fn set(&self) -> &[u64] {
        &self.set
    }

    /// Run this endpoint over a transport to completion. Blocks on `transport.recv()`;
    /// returns the unified report, or the first typed error.
    ///
    /// Consecutive `run` calls on the same `Setx` reuse the previous conversation's
    /// constructed decoder whenever the negotiated matrix comes out identical (the
    /// steady-state re-sync case), skipping the dominant per-session CSR build.
    pub fn run<T: Transport>(&self, transport: &mut T) -> Result<SetxReport, SetxError> {
        let mut ep = Endpoint::new(&self.cfg, &self.set, transport.is_client());
        if let Ok(mut slot) = self.cache.lock() {
            ep.set_cache(std::mem::take(&mut *slot));
        }
        let result = Self::pump(&mut ep, transport);
        if let Ok(mut slot) = self.cache.lock() {
            *slot = ep.take_cache();
        }
        result
    }

    /// The one frame pump every transport-driven run shares: deliver the endpoint's
    /// opening frames, then feed received frames in until it finishes or fails.
    /// (`pub(crate)`: [`crate::server`] workers drive their per-connection endpoints
    /// through this exact loop, so server sessions and `Setx::run` cannot drift.)
    pub(crate) fn pump<T: Transport>(
        ep: &mut Endpoint<'_>,
        transport: &mut T,
    ) -> Result<SetxReport, SetxError> {
        for msg in ep.start() {
            transport.send(&msg)?;
        }
        loop {
            let Some(msg) = transport.recv()? else {
                return Err(SetxError::PeerClosed { during: ep.phase_name() });
            };
            match ep.on_msg(&msg) {
                Step::Send(msgs) => {
                    for m in msgs {
                        transport.send(&m)?;
                    }
                }
                Step::Continue => {}
                Step::Finish(msgs, report) => {
                    for m in msgs {
                        transport.send(&m)?;
                    }
                    return Ok(*report);
                }
                Step::Fatal(msgs, err) => {
                    // Best-effort: let the peer see the final Confirm before we bail.
                    for m in msgs {
                        let _ = transport.send(&m);
                    }
                    return Err(err);
                }
            }
        }
    }

    /// Drive this endpoint (as the client/tie-break side) against `peer` in-process,
    /// deterministically and without threads — the in-memory counterpart of two `run`
    /// calls over [`transport::mem_pair`], and the per-partition primitive of the
    /// partitioned driver.
    pub fn run_pair(&self, peer: &Setx) -> Result<(SetxReport, SetxReport), SetxError> {
        let ours = self.cfg.fingerprint();
        let theirs = peer.cfg.fingerprint();
        if ours != theirs {
            return Err(SetxError::ConfigMismatch { ours, theirs });
        }
        let mut a = Endpoint::new(&self.cfg, &self.set, true);
        let mut b = Endpoint::new(&peer.cfg, &peer.set, false);
        if let Ok(mut slot) = self.cache.lock() {
            a.set_cache(std::mem::take(&mut *slot));
        }
        if let Ok(mut slot) = peer.cache.lock() {
            b.set_cache(std::mem::take(&mut *slot));
        }
        let result = endpoint::drive_endpoints(&mut a, &mut b);
        if let Ok(mut slot) = self.cache.lock() {
            *slot = a.take_cache();
        }
        if let Ok(mut slot) = peer.cache.lock() {
            *slot = b.take_cache();
        }
        result
    }
}

/// The unified outcome of every SetX path: what was computed, how the conversation went,
/// and where every byte was spent.
#[derive(Clone, Debug)]
pub struct SetxReport {
    /// `set ∩ peer_set`, sorted (each endpoint computes its own copy; they agree).
    pub intersection: Vec<u64>,
    /// This endpoint's unique elements `set \ peer_set`, sorted. Empty for the
    /// unidirectional *sender* (the protocol gives it nothing to learn — its set is the
    /// intersection).
    pub local_unique: Vec<u64>,
    /// Which protocol family the (final, successful) attempt ran.
    pub kind: ProtocolKind,
    /// Always true on the `Ok` path; failures surface as [`SetxError::Decode`].
    pub converged: bool,
    /// Decode attempts used (1 = first try; > 1 means the escalation ladder fired).
    pub attempts: u32,
    /// Payload frames exchanged (sketch + residue phases, all attempts, both
    /// directions). For a partitioned aggregate this is the **slowest partition's**
    /// count — partitions run concurrently, so summing would inflate with `parts`.
    pub rounds: usize,
    /// Reconnects [`Setx::run_with_retry`] performed before this successful
    /// conversation (0 = the first connection succeeded; plain [`Setx::run`]
    /// always reports 0). Distinct from [`SetxReport::attempts`], which counts
    /// decode-ladder rungs *within* one conversation.
    pub retries: u32,
    /// Transport bytes burned by the failed attempts behind
    /// [`SetxReport::retries`] (both directions, from the transports' own
    /// counters). **Not** included in [`SetxReport::total_bytes`]/`comm`, which
    /// describe only the successful conversation — this field is the price of
    /// recovery, kept visible and separate.
    pub retry_bytes: usize,
    /// Full conversation transcript at exact wire sizes — both endpoints of a session
    /// record identical totals.
    pub comm: CommLog,
    /// Whether this endpoint is "Alice" (the client end) in the log's direction labels.
    pub(crate) local_is_alice: bool,
    /// Timestamped timeline of the run (handshake, estimate, one span per ladder
    /// attempt, one marker per payload frame, …) — empty when the endpoint ran with
    /// `tracing(false)`, or for partitioned aggregates (partitions run concurrently, so
    /// a single merged timeline would be misleading). See [`crate::obs`].
    pub trace: SessionTrace,
}

impl SetxReport {
    /// Connection attempts consumed end to end: `retries + 1` (the successful
    /// conversation plus every reconnect before it).
    pub fn attempts_used(&self) -> u32 {
        self.retries + 1
    }

    /// Total conversation bytes, both directions — the paper's communication cost.
    pub fn total_bytes(&self) -> usize {
        self.comm.total_bytes()
    }

    /// What the conversation *would* have cost without the columnar wire codec, both
    /// directions. Equals [`SetxReport::total_bytes`] for codec-off sessions.
    pub fn total_raw_bytes(&self) -> usize {
        self.comm.total_raw_bytes()
    }

    /// Encoded ÷ raw bytes over the whole conversation (1.0 = the codec was off or
    /// saved nothing; < 1.0 = net shrink).
    pub fn compression_ratio(&self) -> f64 {
        self.comm.compression_ratio()
    }

    pub fn bytes_sent(&self) -> usize {
        self.direction_bytes(true)
    }

    pub fn bytes_received(&self) -> usize {
        self.direction_bytes(false)
    }

    fn direction_bytes(&self, sent: bool) -> usize {
        self.comm
            .entries
            .iter()
            .filter(|e| (e.from_alice == self.local_is_alice) == sent)
            .map(|e| e.bytes)
            .sum()
    }

    /// Bytes this endpoint sent in one protocol phase.
    pub fn phase_sent(&self, phase: Phase) -> usize {
        self.comm.direction_phase_bytes(self.local_is_alice, phase)
    }

    /// Bytes this endpoint received in one protocol phase.
    pub fn phase_received(&self, phase: Phase) -> usize {
        self.comm.direction_phase_bytes(!self.local_is_alice, phase)
    }

    /// Both directions of one phase.
    pub fn phase_total(&self, phase: Phase) -> usize {
        self.comm.bytes_by_phase(phase)
    }

    /// Per-phase wall time folded from [`SetxReport::trace`] (all zeros when tracing was
    /// off): where the run's time went, the duration counterpart of
    /// [`SetxReport::breakdown`].
    pub fn phase_durations(&self) -> PhaseDurations {
        self.trace.phase_durations()
    }

    /// One-line per-phase breakdown, e.g. for CLI output.
    pub fn breakdown(&self) -> String {
        Phase::ALL
            .iter()
            .map(|&p| format!("{} {} B", p.name(), self.phase_total(p)))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn builder_validates_config() {
        let set: Vec<u64> = (0..10).collect();
        assert!(matches!(
            Setx::builder(&set).safety(0.0).build(),
            Err(SetxError::Config(_))
        ));
        assert!(matches!(
            Setx::builder(&set).max_attempts(0).build(),
            Err(SetxError::Config(_))
        ));
        assert!(matches!(
            Setx::builder(&set).universe_bits(4).build(),
            Err(SetxError::Config(_))
        ));
        assert!(Setx::builder(&set).build().is_ok());
    }

    #[test]
    fn fingerprint_separates_configs() {
        let set: Vec<u64> = (0..10).collect();
        let base = Setx::builder(&set).build().unwrap().cfg.fingerprint();
        let seeded = Setx::builder(&set).seed(1).build().unwrap().cfg.fingerprint();
        let explicit = Setx::builder(&set)
            .diff_size(DiffSize::Explicit(100))
            .build()
            .unwrap()
            .cfg
            .fingerprint();
        let mode = Setx::builder(&set).mode(Mode::Bidi).build().unwrap().cfg.fingerprint();
        assert_ne!(base, seeded);
        assert_ne!(base, explicit);
        assert_ne!(base, mode);
        // And equality for equal configs (the property the handshake relies on).
        assert_eq!(base, Setx::builder(&set).build().unwrap().cfg.fingerprint());
        // encode_threads is a *local* perf knob: peers with different settings must
        // still fingerprint-match (the parallel encode is bit-identical to serial).
        assert_eq!(
            base,
            Setx::builder(&set).encode_threads(4).build().unwrap().cfg.fingerprint()
        );
        // The tenant namespace is routing, not protocol: clients of different tenants
        // must share the server's fingerprint or multi-tenancy could never handshake.
        let tenant9 = Setx::builder(&set).namespace(9).build().unwrap();
        assert_eq!(base, tenant9.cfg.fingerprint());
        assert_eq!(tenant9.cfg.namespace(), 9);
        // The wire codec is framing, not protocol: a codec-off client must still
        // fingerprint-match a codec-on server (they negotiate down in the handshake).
        let plain = Setx::builder(&set).codec(false).build().unwrap();
        assert_eq!(base, plain.cfg.fingerprint());
        assert!(!plain.cfg.engine.codec);
        // Tracing is local observation with zero wire impact: a traced endpoint must
        // still fingerprint-match an untraced (ablation) peer.
        let untraced = Setx::builder(&set).tracing(false).build().unwrap();
        assert_eq!(base, untraced.cfg.fingerprint());
        assert!(!untraced.cfg.tracing);
    }

    #[test]
    fn mismatched_configs_refuse_to_run() {
        let (a, b) = synth::overlap_pair(500, 10, 10, 1);
        let alice = Setx::builder(&a).seed(1).build().unwrap();
        let bob = Setx::builder(&b).seed(2).build().unwrap();
        assert!(matches!(alice.run_pair(&bob), Err(SetxError::ConfigMismatch { .. })));
    }

    /// One instance of every `SetxError` variant — the exhaustive fixture the
    /// classification and Display tests below share. Adding a variant without
    /// extending this list is a compile-visible gap (the tests enumerate it).
    fn every_variant() -> Vec<SetxError> {
        vec![
            SetxError::Config("safety 0 outside [0.2, 8.0]".to_string()),
            SetxError::ConfigMismatch { ours: 0xA, theirs: 0xB },
            SetxError::Io(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "fault: connection dropped",
            )),
            SetxError::PeerClosed { during: "handshake" },
            SetxError::MalformedFrame("fault: flipped frame bytes"),
            SetxError::Protocol(SessionError::UnexpectedMessage {
                phase: "sketch",
                got: "confirm",
            }),
            SetxError::Decode { failure: DecodeFailure::ResidueDecode, attempts: 3 },
            SetxError::ServerBusy { retry_after_ms: 50, namespace: 2 },
        ]
    }

    #[test]
    fn transient_classification_covers_every_variant() {
        // The retry layer's contract: exactly Io / ServerBusy / PeerClosed are
        // worth a fresh connection; everything else reproduces on a clean link.
        for err in every_variant() {
            let expect = matches!(
                err,
                SetxError::Io(_) | SetxError::ServerBusy { .. } | SetxError::PeerClosed { .. }
            );
            assert_eq!(err.is_transient(), expect, "classification drifted for {err:?}");
        }
        let transient = every_variant().iter().filter(|e| e.is_transient()).count();
        assert_eq!(transient, 3);
    }

    #[test]
    fn display_is_stable_on_every_variant() {
        let expected = [
            "invalid config: safety 0 outside [0.2, 8.0]",
            "peer config mismatch (ours 0xa, theirs 0xb)",
            "transport i/o: fault: connection dropped",
            "peer closed during handshake",
            "malformed frame: fault: flipped frame bytes",
            "protocol violation: unexpected confirm frame in sketch phase",
            "residue undecodable after 3 attempt(s)",
            "server at admission capacity for tenant 2 (retry after ~50 ms)",
        ];
        let variants = every_variant();
        assert_eq!(variants.len(), expected.len());
        for (err, want) in variants.iter().zip(expected) {
            assert_eq!(err.to_string(), want, "Display drifted for {err:?}");
        }
        // Io and Protocol expose their cause through `source()`; the rest are leaves.
        for err in &variants {
            let has_source = matches!(err, SetxError::Io(_) | SetxError::Protocol(_));
            assert_eq!(std::error::Error::source(err).is_some(), has_source);
        }
    }
}
