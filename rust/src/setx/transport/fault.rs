//! Deterministic fault injection for any [`Transport`]: the chaos harness's hands.
//!
//! A [`FaultPlan`] is a declarative list of rules — *which* fault
//! ([`FaultKind`]), *where* (a [`Phase`] filter plus send/recv direction), and
//! *when* (either the n-th matching frame, or per-frame with a seeded
//! probability). [`FaultPlan::injector`] freezes the plan into a shared
//! [`FaultInjector`], and [`FaultInjector::wrap`] puts a [`FaultTransport`]
//! around a real transport. Everything downstream of the seed is deterministic:
//! the same plan over the same conversation fires the same faults at the same
//! frames, every run — which is what lets `rust/tests/chaos.rs` assert *exact*
//! outcomes instead of "something probably broke".
//!
//! # What each fault looks like to the protocol
//!
//! Faults are modeled at the frame layer as the *receiver-visible effect* the
//! real-world failure would have after the framing layer
//! ([`super::frame_extent`] / [`super::read_frame`]) has done its validation:
//!
//! * [`FaultKind::DropConnection`] — the conversation dies at this frame. The
//!   faulted operation (and every later one) returns [`SetxError::Io`] with kind
//!   `ConnectionReset`/`BrokenPipe` — **transient**, exactly what a retry layer
//!   must survive.
//! * [`FaultKind::TruncateFrame`] — the peer closed mid-frame. On the recv side
//!   this surfaces as [`SetxError::Io`] (kind `UnexpectedEof`), matching what
//!   [`super::read_frame`] reports for a short body — **transient**. On the send
//!   side the damaged frame is silently swallowed and the stream marked dead
//!   (the local writer can't see its own truncation; it sees the *next* I/O
//!   fail).
//! * [`FaultKind::FlipBytes`] — frame corruption that desynchronizes the
//!   framing layer: the receiver observes [`SetxError::MalformedFrame`] —
//!   a **fatal** protocol fault (retrying a corrupting link re-corrupts). On
//!   the send side it behaves like a truncation for the local end.
//! * [`FaultKind::Delay`] — the frame is delivered intact, late. Time is
//!   *simulated*: when the plan carries a [`ManualClock`]
//!   ([`FaultPlan::manual_clock`]) the clock is advanced by `delay_ns`; there is
//!   never a real sleep, so chaos tests stay fast and deterministic.
//! * [`FaultKind::DuplicateFrame`] — the frame arrives (or is sent) twice;
//!   duplicates surface out of phase and the sans-io state machines must reject
//!   them with a typed error, never mis-merge them.
//!
//! Every fired fault is recorded in the [`FaultLog`] — kind, phase, direction,
//! global frame index, and the clock reading — so tests assert exactly which
//! faults fired, not just that *something* did.
//!
//! ```
//! use commonsense::metrics::Phase;
//! use commonsense::setx::transport::{mem_pair, FaultKind, FaultPlan};
//!
//! let injector = FaultPlan::new(7)
//!     .fail_nth(FaultKind::DropConnection, Some(Phase::Residue), 2)
//!     .injector();
//! let (client, _server) = mem_pair();
//! let mut faulty = injector.wrap(client);
//! // ... drive a session over `faulty`: the 2nd Residue-phase frame kills the
//! // connection, and `injector.log()` proves it afterwards ...
//! # let _ = &mut faulty;
//! ```

use super::{SetxError, Transport};
use crate::hash::split_mix64;
use crate::metrics::Phase;
use crate::obs::{default_clock, Clock, ManualClock};
use crate::protocol::wire::Msg;
use std::sync::{Arc, Mutex};

/// The injectable failure modes. See the module docs for the receiver-visible
/// semantics of each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The connection dies at this frame; every later operation fails with
    /// [`SetxError::Io`].
    DropConnection,
    /// The frame is cut mid-body and the stream ends: [`SetxError::Io`]
    /// (`UnexpectedEof`) on the receiving side.
    TruncateFrame,
    /// The frame is corrupted in flight: [`SetxError::MalformedFrame`] on the
    /// receiving side.
    FlipBytes,
    /// The frame is delivered intact after `delay_ns` of *simulated* time.
    Delay,
    /// The frame is delivered twice.
    DuplicateFrame,
}

impl FaultKind {
    /// Stable lowercase name, for logs and bench-row labels.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::DropConnection => "drop_connection",
            FaultKind::TruncateFrame => "truncate_frame",
            FaultKind::FlipBytes => "flip_bytes",
            FaultKind::Delay => "delay",
            FaultKind::DuplicateFrame => "duplicate_frame",
        }
    }
}

/// Which side of the wrapped transport a rule watches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultDirection {
    /// Frames this endpoint sends.
    Send,
    /// Frames this endpoint receives.
    Recv,
    /// Either direction.
    Any,
}

impl FaultDirection {
    fn matches(self, sending: bool) -> bool {
        match self {
            FaultDirection::Send => sending,
            FaultDirection::Recv => !sending,
            FaultDirection::Any => true,
        }
    }
}

/// One declarative fault rule: *kind* × *where* (phase + direction) × *when*
/// (n-th matching frame, or a per-frame probability).
#[derive(Clone, Debug)]
pub struct FaultRule {
    pub kind: FaultKind,
    /// Restrict to frames of this protocol phase; `None` matches every phase.
    /// Frames map to phases by message type: `EstHello`/`Hello`/`Busy` →
    /// Handshake, `Sketch`/`AggSketch` → Sketch, `Round`/`MultiResidue` →
    /// Residue, `Confirm` → Confirm.
    pub phase: Option<Phase>,
    pub direction: FaultDirection,
    /// Fire on exactly the n-th (1-based) matching frame, once. `None` means
    /// probabilistic: every matching frame fires independently with
    /// `probability`.
    pub nth: Option<u32>,
    /// Per-frame firing probability in `[0, 1]`, used only when `nth` is `None`.
    /// The coin is `split_mix64(seed, rule, frame)` — seeded, so reruns agree.
    pub probability: f64,
    /// Simulated latency for [`FaultKind::Delay`]; ignored by other kinds.
    pub delay_ns: u64,
}

/// One fired fault, as recorded in the [`FaultLog`].
#[derive(Clone, Debug)]
pub struct FaultEvent {
    pub kind: FaultKind,
    /// Phase of the frame the fault hit.
    pub phase: Phase,
    /// `true` if the fault hit a frame this endpoint was sending.
    pub sending: bool,
    /// Index of the frame among *all* frames that crossed this injector's
    /// transports (0-based, both directions, counted across reconnects).
    pub frame_index: u64,
    /// Clock reading when the fault fired (the plan's [`ManualClock`] when one
    /// is attached, the process monotonic clock otherwise).
    pub at_ns: u64,
}

/// The record of every fault that actually fired, in firing order.
#[derive(Clone, Debug, Default)]
pub struct FaultLog {
    events: Vec<FaultEvent>,
}

impl FaultLog {
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// How many fired events were of `kind`.
    pub fn count(&self, kind: FaultKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }
}

/// A seeded, declarative schedule of faults. Build one with the chainable
/// constructors, then freeze it into a [`FaultInjector`] (rules are immutable
/// from then on; only counters and the log evolve).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
    manual: Option<Arc<ManualClock>>,
}

impl FaultPlan {
    /// An empty plan (a transparent wrapper) with the given probability seed.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: Vec::new(), manual: None }
    }

    /// Append a fully spelled-out rule.
    pub fn rule(mut self, rule: FaultRule) -> FaultPlan {
        self.rules.push(rule);
        self
    }

    /// Fire `kind` on exactly the n-th (1-based) frame of `phase` (any phase if
    /// `None`), in either direction — e.g. `fail_nth(DropConnection,
    /// Some(Phase::Residue), 2)` kills the 2nd Residue frame.
    pub fn fail_nth(self, kind: FaultKind, phase: Option<Phase>, nth: u32) -> FaultPlan {
        self.rule(FaultRule {
            kind,
            phase,
            direction: FaultDirection::Any,
            nth: Some(nth.max(1)),
            probability: 0.0,
            delay_ns: 0,
        })
    }

    /// Fire `kind` on every matching frame independently with probability `p`.
    pub fn fail_with_probability(
        self,
        kind: FaultKind,
        phase: Option<Phase>,
        p: f64,
    ) -> FaultPlan {
        self.rule(FaultRule {
            kind,
            phase,
            direction: FaultDirection::Any,
            nth: None,
            probability: p.clamp(0.0, 1.0),
            delay_ns: 0,
        })
    }

    /// Delay the n-th matching frame by `delay_ns` of simulated time (the
    /// attached [`ManualClock`] is advanced; nothing sleeps).
    pub fn delay_nth(self, phase: Option<Phase>, nth: u32, delay_ns: u64) -> FaultPlan {
        self.rule(FaultRule {
            kind: FaultKind::Delay,
            phase,
            direction: FaultDirection::Any,
            nth: Some(nth.max(1)),
            probability: 0.0,
            delay_ns,
        })
    }

    /// Attach a [`ManualClock`]: [`FaultKind::Delay`] advances it, and every
    /// [`FaultEvent::at_ns`] is stamped from it. Without one, events are stamped
    /// from the process monotonic clock and delays only log.
    pub fn manual_clock(mut self, clock: Arc<ManualClock>) -> FaultPlan {
        self.manual = Some(clock);
        self
    }

    /// Freeze the plan into a shareable injector. One injector can wrap many
    /// transports in turn (e.g. each reconnect of a retry loop): rule counters
    /// and the log persist across wraps, so an `nth`-style rule that already
    /// fired leaves later connections clean — the shape retry-convergence tests
    /// rely on.
    pub fn injector(self) -> FaultInjector {
        let clock: Arc<dyn Clock> = match &self.manual {
            Some(m) => Arc::clone(m) as Arc<dyn Clock>,
            None => default_clock(),
        };
        let hits = vec![0u64; self.rules.len()];
        let fired = vec![0u64; self.rules.len()];
        FaultInjector {
            shared: Arc::new(Mutex::new(InjectorState {
                plan: self,
                clock,
                rule_hits: hits,
                rule_fired: fired,
                frames: 0,
                log: FaultLog::default(),
            })),
        }
    }
}

struct InjectorState {
    plan: FaultPlan,
    clock: Arc<dyn Clock>,
    /// Per rule: how many frames have matched its (phase, direction) filter.
    rule_hits: Vec<u64>,
    /// Per rule: how many times it has fired (an `nth` rule fires at most once).
    rule_fired: Vec<u64>,
    /// Frames observed across all wrapped transports, both directions.
    frames: u64,
    log: FaultLog,
}

impl InjectorState {
    /// Classify a frame, advance every matching rule's counter, and return the
    /// first rule that fires (with its delay), recording it in the log.
    fn decide(&mut self, sending: bool, msg: &Msg) -> Option<(FaultKind, u64)> {
        let phase = phase_of(msg);
        let frame_index = self.frames;
        self.frames += 1;
        let mut fired: Option<(FaultKind, u64)> = None;
        for (i, rule) in self.plan.rules.iter().enumerate() {
            let phase_ok = rule.phase.map_or(true, |p| p == phase);
            if !phase_ok || !rule.direction.matches(sending) {
                continue;
            }
            self.rule_hits[i] += 1;
            if fired.is_some() {
                continue;
            }
            let fire = match rule.nth {
                Some(n) => self.rule_fired[i] == 0 && self.rule_hits[i] == u64::from(n),
                None => coin(self.plan.seed, i as u64, self.rule_hits[i]) < rule.probability,
            };
            if fire {
                self.rule_fired[i] += 1;
                fired = Some((rule.kind, rule.delay_ns));
            }
        }
        if let Some((kind, delay_ns)) = fired {
            if kind == FaultKind::Delay {
                if let Some(m) = &self.plan.manual {
                    m.advance(delay_ns);
                }
            }
            let at_ns = self.clock.now_ns();
            self.log.events.push(FaultEvent { kind, phase, sending, frame_index, at_ns });
        }
        fired
    }
}

/// Deterministic per-(seed, rule, frame) coin in `[0, 1)`.
fn coin(seed: u64, rule: u64, hit: u64) -> f64 {
    let r = split_mix64(seed ^ rule.rotate_left(48) ^ hit.rotate_left(17));
    (r >> 11) as f64 / (1u64 << 53) as f64
}

/// Protocol phase of a frame, by message type — delegated to the accounting
/// layer's classifier so fault targeting and byte accounting can never drift
/// apart.
fn phase_of(msg: &Msg) -> Phase {
    crate::protocol::session::frame_phase(msg)
}

/// The frozen, shareable form of a [`FaultPlan`]: wrap transports with it, then
/// read back [`FaultInjector::log`] to assert exactly what fired.
#[derive(Clone)]
pub struct FaultInjector {
    shared: Arc<Mutex<InjectorState>>,
}

impl FaultInjector {
    /// Wrap a transport. Counters and the log are shared with every other
    /// transport wrapped by this injector (past or future).
    pub fn wrap<T: Transport>(&self, inner: T) -> FaultTransport<T> {
        FaultTransport {
            inner,
            shared: Arc::clone(&self.shared),
            dead: None,
            pending: None,
        }
    }

    /// Snapshot of the log of fired faults.
    pub fn log(&self) -> FaultLog {
        self.shared.lock().expect("fault injector poisoned").log.clone()
    }

    /// Total faults fired so far.
    pub fn fired(&self) -> usize {
        self.shared.lock().expect("fault injector poisoned").log.len()
    }

    /// Total frames observed (both directions, all wrapped transports).
    pub fn frames_seen(&self) -> u64 {
        self.shared.lock().expect("fault injector poisoned").frames
    }
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let st = self.shared.lock().expect("fault injector poisoned");
        f.debug_struct("FaultInjector")
            .field("rules", &st.plan.rules.len())
            .field("frames", &st.frames)
            .field("fired", &st.log.len())
            .finish()
    }
}

/// A [`Transport`] decorator that applies a [`FaultPlan`] to the frames passing
/// through it. Obtain via [`FaultInjector::wrap`].
pub struct FaultTransport<T: Transport> {
    inner: T,
    shared: Arc<Mutex<InjectorState>>,
    /// `Some(reason)` once a connection-killing fault fired: every later
    /// operation fails with a transient I/O error, like a real dead socket.
    dead: Option<&'static str>,
    /// A duplicate frame awaiting redelivery on the next `recv`.
    pending: Option<Msg>,
}

impl<T: Transport> FaultTransport<T> {
    /// The wrapped transport (e.g. to read its byte counters).
    pub fn inner(&self) -> &T {
        &self.inner
    }

    pub fn into_inner(self) -> T {
        self.inner
    }

    fn decide(&self, sending: bool, msg: &Msg) -> Option<(FaultKind, u64)> {
        self.shared.lock().expect("fault injector poisoned").decide(sending, msg)
    }

    fn dead_err(reason: &'static str, kind: std::io::ErrorKind) -> SetxError {
        SetxError::Io(std::io::Error::new(kind, reason))
    }
}

impl<T: Transport> Transport for FaultTransport<T> {
    fn send(&mut self, msg: &Msg) -> Result<(), SetxError> {
        if let Some(reason) = self.dead {
            return Err(Self::dead_err(reason, std::io::ErrorKind::BrokenPipe));
        }
        match self.decide(true, msg) {
            None => self.inner.send(msg),
            Some((FaultKind::DropConnection, _)) => {
                self.dead = Some("fault: connection dropped");
                Err(Self::dead_err(
                    "fault: connection dropped",
                    std::io::ErrorKind::ConnectionReset,
                ))
            }
            // A frame damaged on the way out: the local writer observes success
            // (the bytes left its buffer) and the stream is dead from here — the
            // peer never sees a complete frame, this end fails on its next I/O.
            Some((FaultKind::TruncateFrame, _)) => {
                self.dead = Some("fault: truncated frame in flight");
                Ok(())
            }
            Some((FaultKind::FlipBytes, _)) => {
                self.dead = Some("fault: corrupted frame in flight");
                Ok(())
            }
            Some((FaultKind::Delay, _)) => self.inner.send(msg),
            Some((FaultKind::DuplicateFrame, _)) => {
                self.inner.send(msg)?;
                self.inner.send(msg)
            }
        }
    }

    fn recv(&mut self) -> Result<Option<Msg>, SetxError> {
        if let Some(reason) = self.dead {
            return Err(Self::dead_err(reason, std::io::ErrorKind::BrokenPipe));
        }
        if let Some(dup) = self.pending.take() {
            return Ok(Some(dup));
        }
        let Some(msg) = self.inner.recv()? else {
            return Ok(None);
        };
        match self.decide(false, &msg) {
            None | Some((FaultKind::Delay, _)) => Ok(Some(msg)),
            Some((FaultKind::DropConnection, _)) => {
                self.dead = Some("fault: connection dropped");
                Err(Self::dead_err(
                    "fault: connection dropped",
                    std::io::ErrorKind::ConnectionReset,
                ))
            }
            Some((FaultKind::TruncateFrame, _)) => {
                self.dead = Some("fault: truncated frame");
                Err(Self::dead_err(
                    "fault: truncated frame",
                    std::io::ErrorKind::UnexpectedEof,
                ))
            }
            Some((FaultKind::FlipBytes, _)) => {
                self.dead = Some("fault: flipped frame bytes");
                Err(SetxError::MalformedFrame("fault: flipped frame bytes"))
            }
            Some((FaultKind::DuplicateFrame, _)) => {
                self.pending = Some(msg.clone());
                Ok(Some(msg))
            }
        }
    }

    fn is_client(&self) -> bool {
        self.inner.is_client()
    }

    fn bytes_moved(&self) -> Option<(usize, usize)> {
        self.inner.bytes_moved()
    }
}

#[cfg(test)]
mod tests {
    use super::super::mem_pair;
    use super::*;
    use crate::protocol::wire;

    fn round_msg() -> Msg {
        Msg::Round {
            residue: vec![1, 2],
            smf: None,
            inquiry: vec![],
            answers: vec![],
            done: false,
            codec: false,
        }
    }

    #[test]
    fn empty_plan_is_transparent() {
        let injector = FaultPlan::new(1).injector();
        let (a, b) = mem_pair();
        let mut fa = injector.wrap(a);
        let mut fb = injector.wrap(b);
        let msg = Msg::Confirm { ok: true, reason: wire::REASON_OK, attempt: 1 };
        fa.send(&msg).unwrap();
        assert_eq!(fb.recv().unwrap().unwrap(), msg);
        assert!(injector.log().is_empty());
        assert_eq!(injector.frames_seen(), 2); // counted on both ends
        assert_eq!(fa.bytes_moved(), Some((msg.wire_len(), 0)));
    }

    #[test]
    fn nth_rule_kills_exactly_the_second_residue_frame() {
        let injector = FaultPlan::new(9)
            .fail_nth(FaultKind::DropConnection, Some(Phase::Residue), 2)
            .injector();
        let (a, b) = mem_pair();
        let mut fa = injector.wrap(a);
        // Handshake-phase frames never match the rule.
        fa.send(&Msg::Confirm { ok: true, reason: wire::REASON_OK, attempt: 1 })
            .unwrap();
        fa.send(&round_msg()).unwrap(); // 1st residue frame: clean
        let err = fa.send(&round_msg()).unwrap_err(); // 2nd: the kill
        assert!(matches!(err, SetxError::Io(_)));
        assert!(err.is_transient());
        // Dead from here on, for sends and recvs alike.
        assert!(matches!(fa.send(&round_msg()), Err(SetxError::Io(_))));
        assert!(matches!(fa.recv(), Err(SetxError::Io(_))));
        let log = injector.log();
        assert_eq!(log.len(), 1);
        assert_eq!(log.events()[0].kind, FaultKind::DropConnection);
        assert_eq!(log.events()[0].phase, Phase::Residue);
        assert!(log.events()[0].sending);
        assert_eq!(log.events()[0].frame_index, 2);
        drop(fa);
        // The peer sees a clean channel close (the in-memory analogue of RST).
        let mut fb = injector.wrap(b);
        while let Ok(Some(_)) = fb.recv() {}
    }

    #[test]
    fn recv_side_faults_surface_with_their_typed_errors() {
        // Truncation → transient Io(UnexpectedEof).
        let injector = FaultPlan::new(3)
            .fail_nth(FaultKind::TruncateFrame, None, 1)
            .injector();
        let (a, b) = mem_pair();
        let mut fb = injector.wrap(b);
        let mut raw = a;
        raw.send(&round_msg()).unwrap();
        match fb.recv() {
            Err(SetxError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof)
            }
            other => panic!("expected truncation Io error, got {other:?}"),
        }
        // Flip → fatal MalformedFrame.
        let injector =
            FaultPlan::new(3).fail_nth(FaultKind::FlipBytes, None, 1).injector();
        let (a, b) = mem_pair();
        let mut fb = injector.wrap(b);
        let mut raw = a;
        raw.send(&round_msg()).unwrap();
        let err = fb.recv().unwrap_err();
        assert!(matches!(err, SetxError::MalformedFrame(_)));
        assert!(!err.is_transient());
    }

    #[test]
    fn duplicate_delivers_the_frame_twice() {
        let injector = FaultPlan::new(5)
            .fail_nth(FaultKind::DuplicateFrame, None, 1)
            .injector();
        let (a, b) = mem_pair();
        let mut fa = injector.wrap(a);
        let mut fb = injector.wrap(b);
        fa.send(&round_msg()).unwrap();
        // Sent once (rule fired on the recv side? no — first matching frame is the
        // send): the send-side duplicate puts two frames on the wire.
        assert_eq!(fb.recv().unwrap().unwrap(), round_msg());
        assert_eq!(fb.recv().unwrap().unwrap(), round_msg());
        assert_eq!(injector.log().count(FaultKind::DuplicateFrame), 1);
    }

    #[test]
    fn delay_advances_the_manual_clock_and_never_sleeps() {
        let clock = Arc::new(ManualClock::new(1_000));
        let injector = FaultPlan::new(2)
            .delay_nth(None, 1, 5_000_000)
            .manual_clock(Arc::clone(&clock))
            .injector();
        let (a, b) = mem_pair();
        let mut fa = injector.wrap(a);
        let mut fb = injector.wrap(b);
        let t0 = std::time::Instant::now();
        fa.send(&round_msg()).unwrap();
        assert_eq!(fb.recv().unwrap().unwrap(), round_msg());
        assert!(t0.elapsed() < std::time::Duration::from_millis(500));
        assert_eq!(clock.now_ns(), 1_000 + 5_000_000);
        let log = injector.log();
        assert_eq!(log.count(FaultKind::Delay), 1);
        assert_eq!(log.events()[0].at_ns, 1_000 + 5_000_000);
    }

    #[test]
    fn probabilistic_rules_are_seed_deterministic() {
        let fires = |seed: u64| -> Vec<u64> {
            let injector = FaultPlan::new(seed)
                .fail_with_probability(FaultKind::DuplicateFrame, None, 0.3)
                .injector();
            let (a, _b) = mem_pair();
            let mut fa = injector.wrap(a);
            for _ in 0..64 {
                let _ = fa.send(&round_msg());
            }
            injector.log().events().iter().map(|e| e.frame_index).collect()
        };
        let first = fires(0xDEAD);
        assert_eq!(first, fires(0xDEAD), "same seed, same schedule");
        assert!(!first.is_empty(), "p=0.3 over 64 frames must fire");
        assert_ne!(first, fires(0xBEEF), "different seed, different schedule");
    }
}
