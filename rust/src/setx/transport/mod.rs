//! The byte-moving half of the facade: the [`Transport`] trait and its two built-in
//! implementations (in-memory channel pair, TCP socket).
//!
//! # The `Transport` contract
//!
//! A transport carries whole [`Msg`] frames between exactly two endpoints. Implementors
//! must provide:
//!
//! * **Framing** — `send` delivers one complete frame; `recv` returns one complete frame.
//!   On a byte stream this means the wire encoding of [`Msg`] (`type:u8 | len:varint |
//!   body`, see [`crate::protocol::wire`]); reads must validate the advertised body
//!   length against [`crate::protocol::wire::MAX_FRAME_BYTES`] *before* sizing any buffer
//!   by it.
//! * **Ordering** — frames arrive exactly once, in send order, with no interleaving from
//!   other conversations. One transport value = one conversation.
//! * **Close semantics** — the endpoint that finishes last simply drops its transport;
//!   nobody sends a close frame. `recv` must return `Ok(None)` for a peer that
//!   disconnected cleanly *at a frame boundary* and `Err` for a mid-frame or corrupt
//!   disconnect. After the protocol reports `Finish`, the driver stops receiving, so a
//!   late peer teardown is never observed as an error.
//! * **Role** — `is_client` says which end of the rendezvous this is (connector vs
//!   acceptor). The protocol uses it only to break ties deterministically (initiator
//!   election, accounting direction); it carries no privilege.
//!
//! Blocking `recv` is assumed; the facade has no internal timeouts. For sockets, use
//! [`TcpTransport::set_timeouts`] (or [`TcpTransport::accept_with_timeouts`]) to bound
//! how long a stalled peer can hold a `recv`/`send`. The multi-client
//! [`crate::server::SetxServer`] does **not** use this blocking transport at all: its
//! readiness-based driver runs non-blocking sockets through [`frame_extent`] and
//! enforces per-connection deadlines itself, so a stalled peer costs a table slot, not
//! a thread.

pub mod fault;

pub use fault::{FaultInjector, FaultKind, FaultLog, FaultPlan, FaultTransport};

use super::SetxError;
use crate::protocol::wire::{self, Msg};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};

/// One end of a two-party frame conversation (see the module docs for the contract).
pub trait Transport {
    /// Deliver one frame to the peer.
    fn send(&mut self, msg: &Msg) -> Result<(), SetxError>;
    /// Block for the peer's next frame; `Ok(None)` = clean close at a frame boundary.
    fn recv(&mut self) -> Result<Option<Msg>, SetxError>;
    /// Which end of the rendezvous this is (deterministic tie-breaks only).
    fn is_client(&self) -> bool;
    /// `(sent, received)` byte counters, when this transport keeps them. The retry
    /// layer uses this to charge a failed attempt's traffic to
    /// [`super::SetxReport::retry_bytes`]; transports without counters return `None`
    /// and the cost of their failed attempts is simply not accounted.
    fn bytes_moved(&self) -> Option<(usize, usize)> {
        None
    }
}

/// In-process channel transport. Frames cross through their real wire encoding, so byte
/// accounting and parser behavior are identical to a socket run; the per-direction
/// transcript is kept for determinism tests.
pub struct MemTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    client: bool,
    pub bytes_sent: usize,
    pub bytes_received: usize,
    /// Every frame this end sent, serialized, in order.
    pub sent_frames: Vec<Vec<u8>>,
}

/// A connected pair of in-memory transports: `(client end, server end)`.
pub fn mem_pair() -> (MemTransport, MemTransport) {
    let (tx_ab, rx_ab) = channel();
    let (tx_ba, rx_ba) = channel();
    (
        MemTransport {
            tx: tx_ab,
            rx: rx_ba,
            client: true,
            bytes_sent: 0,
            bytes_received: 0,
            sent_frames: Vec::new(),
        },
        MemTransport {
            tx: tx_ba,
            rx: rx_ab,
            client: false,
            bytes_sent: 0,
            bytes_received: 0,
            sent_frames: Vec::new(),
        },
    )
}

impl Transport for MemTransport {
    fn send(&mut self, msg: &Msg) -> Result<(), SetxError> {
        let bytes = msg.to_bytes();
        self.bytes_sent += bytes.len();
        self.sent_frames.push(bytes.clone());
        self.tx
            .send(bytes)
            .map_err(|_| SetxError::PeerClosed { during: "in-memory send" })
    }

    fn recv(&mut self) -> Result<Option<Msg>, SetxError> {
        let Ok(bytes) = self.rx.recv() else {
            return Ok(None); // peer dropped its end: clean close
        };
        self.bytes_received += bytes.len();
        let (msg, used) =
            Msg::from_bytes(&bytes).ok_or(SetxError::MalformedFrame("in-memory frame"))?;
        if used != bytes.len() {
            return Err(SetxError::MalformedFrame("in-memory frame trailing bytes"));
        }
        Ok(Some(msg))
    }

    fn is_client(&self) -> bool {
        self.client
    }

    fn bytes_moved(&self) -> Option<(usize, usize)> {
        Some((self.bytes_sent, self.bytes_received))
    }
}

/// TCP socket transport: length-prefixed frames hardened against adversarial length
/// fields, with byte counters for wire-accounting cross-checks. The byte counts are
/// ground truth (what actually crossed the socket); tests assert they equal the
/// protocol's own [`crate::metrics::CommLog`] totals.
pub struct TcpTransport {
    stream: TcpStream,
    client: bool,
    pub bytes_sent: usize,
    pub bytes_received: usize,
}

impl TcpTransport {
    /// Connect to a listening peer (this end becomes the client).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<TcpTransport, SetxError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(TcpTransport::from_stream(stream, true))
    }

    /// Accept one connection from a bound listener (this end becomes the server).
    pub fn accept(listener: &TcpListener) -> Result<TcpTransport, SetxError> {
        Self::accept_with_timeouts(listener, None, None)
    }

    /// [`TcpTransport::accept`] with OS-level read/write timeouts applied before any
    /// frame I/O — the shared accept helper behind both the one-shot
    /// [`crate::coordinator::tcp::serve`] and every [`crate::server::SetxServer`]
    /// worker connection.
    pub fn accept_with_timeouts(
        listener: &TcpListener,
        read: Option<std::time::Duration>,
        write: Option<std::time::Duration>,
    ) -> Result<TcpTransport, SetxError> {
        let (stream, _addr) = listener.accept()?;
        stream.set_nodelay(true).ok();
        let transport = TcpTransport::from_stream(stream, false);
        transport.set_timeouts(read, write)?;
        Ok(transport)
    }

    /// Wrap an already-connected stream. `client` must reflect which side initiated the
    /// connection (or any out-of-band agreement — the two ends must disagree).
    pub fn from_stream(stream: TcpStream, client: bool) -> TcpTransport {
        TcpTransport { stream, client, bytes_sent: 0, bytes_received: 0 }
    }

    /// Bound every subsequent socket read/write: a peer that stalls mid-conversation
    /// longer than the timeout turns the blocked `recv`/`send` into a
    /// [`SetxError::Io`] (kind `WouldBlock`/`TimedOut`) instead of wedging the calling
    /// thread forever. `None` restores OS-default blocking. Frame reads are not resumable
    /// after a timeout — treat the session as failed and drop the transport.
    pub fn set_timeouts(
        &self,
        read: Option<std::time::Duration>,
        write: Option<std::time::Duration>,
    ) -> Result<(), SetxError> {
        self.stream.set_read_timeout(read)?;
        self.stream.set_write_timeout(write)?;
        Ok(())
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<(), SetxError> {
        let bytes = msg.to_bytes();
        self.stream.write_all(&bytes)?;
        self.bytes_sent += bytes.len();
        Ok(())
    }

    fn recv(&mut self) -> Result<Option<Msg>, SetxError> {
        let (msg, bytes_read) = read_frame(&mut self.stream)?;
        self.bytes_received += bytes_read;
        Ok(msg)
    }

    fn is_client(&self) -> bool {
        self.client
    }

    fn bytes_moved(&self) -> Option<(usize, usize)> {
        Some((self.bytes_sent, self.bytes_received))
    }
}

/// Read exactly one frame from a stream: type byte + varint length + body. The framer
/// is deliberately type-byte-agnostic — codec-on frame types flow through it unchanged
/// (only [`Msg::from_bytes`] interprets the type), so the transport needed no changes
/// for the columnar codec. Returns
/// `(Ok(None), 0)`-style on a clean end-of-stream at a frame boundary (the peer tore down
/// after finishing); anything else — EOF mid-frame, a malformed frame, an adversarial
/// length field — is an error. The advertised body length is validated against
/// [`wire::MAX_FRAME_BYTES`] *before* any buffer is sized by it, so a hostile peer cannot
/// drive a huge allocation with a 10-byte header. The returned count is the exact number
/// of bytes consumed from the socket.
pub(crate) fn read_frame(stream: &mut TcpStream) -> Result<(Option<Msg>, usize), SetxError> {
    let mut byte = [0u8; 1];
    match stream.read_exact(&mut byte) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok((None, 0)),
        Err(e) => return Err(SetxError::Io(e)),
    }
    let mut frame = vec![byte[0]];
    // Varint body length, byte by byte.
    let mut len = 0u64;
    let mut shift = 0u32;
    let mut more = true;
    while more {
        stream.read_exact(&mut byte)?;
        frame.push(byte[0]);
        len |= ((byte[0] & 0x7f) as u64) << shift;
        more = byte[0] & 0x80 != 0;
        if more {
            shift += 7;
            if shift >= 64 {
                return Err(SetxError::MalformedFrame("frame length varint overflow"));
            }
        }
    }
    let len = usize::try_from(len)
        .map_err(|_| SetxError::MalformedFrame("frame length exceeds address space"))?;
    if len > wire::MAX_FRAME_BYTES {
        return Err(SetxError::MalformedFrame("frame length exceeds cap"));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    frame.extend_from_slice(&body);
    let total = frame.len();
    let (msg, used) =
        Msg::from_bytes(&frame).ok_or(SetxError::MalformedFrame("unparseable frame"))?;
    if used != total {
        return Err(SetxError::MalformedFrame("frame parser length mismatch"));
    }
    Ok((Some(msg), total))
}

/// Frame-boundary scan for non-blocking drivers: given the bytes buffered so far, how
/// long (in bytes) is the first complete frame? `Ok(None)` means the header or body is
/// still incomplete — read more and retry. `Err` means the buffered header can never
/// become a valid frame (varint overflow, or a body length beyond
/// [`wire::MAX_FRAME_BYTES`]) — the connection is corrupt and must be dropped. This is
/// the header-first mirror of [`read_frame`] for sockets that deliver partial frames:
/// the length is validated *before* any buffer is sized by it, and — unlike
/// [`Msg::from_bytes`], which returns `None` for both — it distinguishes
/// "need more bytes" from "garbage".
pub(crate) fn frame_extent(buf: &[u8]) -> Result<Option<usize>, &'static str> {
    let mut len = 0u64;
    let mut shift = 0u32;
    let mut i = 1usize; // the type byte needs no validation here
    loop {
        let Some(&b) = buf.get(i) else { return Ok(None) };
        i += 1;
        len |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            break;
        }
        shift += 7;
        if shift >= 64 {
            return Err("frame length varint overflow");
        }
    }
    let Ok(len) = usize::try_from(len) else {
        return Err("frame length exceeds address space");
    };
    if len > wire::MAX_FRAME_BYTES {
        return Err("frame length exceeds cap");
    }
    Ok(if buf.len() < i + len { None } else { Some(i + len) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::put_varint;

    #[test]
    fn mem_pair_moves_frames_and_counts_bytes() {
        let (mut a, mut b) = mem_pair();
        assert!(a.is_client() && !b.is_client());
        let msg = Msg::Round {
            residue: vec![1, 2, 3],
            smf: None,
            inquiry: vec![9],
            answers: vec![true],
            done: false,
            codec: false,
        };
        a.send(&msg).unwrap();
        let got = b.recv().unwrap().unwrap();
        assert_eq!(got, msg);
        assert_eq!(a.bytes_sent, msg.wire_len());
        assert_eq!(b.bytes_received, msg.wire_len());
        assert_eq!(a.sent_frames.len(), 1);
        // Dropping one end closes the conversation cleanly.
        drop(a);
        assert!(matches!(b.recv(), Ok(None)));
    }

    #[test]
    fn tcp_transport_roundtrips_frames() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let join = std::thread::spawn(move || {
            let mut client = TcpTransport::connect(addr).unwrap();
            let msg = Msg::Confirm { ok: true, reason: wire::REASON_OK, attempt: 2 };
            client.send(&msg).unwrap();
            client
        });
        let mut server = TcpTransport::accept(&listener).unwrap();
        let got = server.recv().unwrap().unwrap();
        assert_eq!(got, Msg::Confirm { ok: true, reason: wire::REASON_OK, attempt: 2 });
        assert_eq!(server.bytes_received, got.wire_len());
        let client = join.join().unwrap();
        assert!(client.is_client() && !server.is_client());
        // Clean teardown: the client dropped, so the server sees a frame-boundary close.
        assert!(matches!(server.recv(), Ok(None)));
    }

    #[test]
    fn read_timeout_turns_stalled_peer_into_io_error() {
        use std::time::Duration;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stalled = std::thread::spawn(move || {
            // Connect, then send nothing for far longer than the server's read timeout.
            let s = TcpStream::connect(addr).unwrap();
            std::thread::sleep(Duration::from_millis(400));
            drop(s);
        });
        let mut server = TcpTransport::accept_with_timeouts(
            &listener,
            Some(Duration::from_millis(50)),
            Some(Duration::from_millis(50)),
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        match server.recv() {
            Err(SetxError::Io(e)) => assert!(
                matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ),
                "unexpected io kind {:?}",
                e.kind()
            ),
            other => panic!("stalled peer must surface as Io timeout, got {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_millis(350),
            "recv must return at the timeout, not at peer close"
        );
        stalled.join().unwrap();
    }

    #[test]
    fn read_frame_rejects_adversarial_length_before_allocating() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // A Round frame claiming a 2^62-byte body; the socket then stays open, so a
            // reader that trusted the length would hang allocating/reading forever.
            let mut frame = vec![3u8];
            put_varint(&mut frame, 1u64 << 62);
            s.write_all(&frame).unwrap();
            s
        });
        let (mut stream, _) = listener.accept().unwrap();
        assert!(read_frame(&mut stream).is_err());
        drop(writer.join().unwrap());
    }

    #[test]
    fn frame_extent_distinguishes_incomplete_from_corrupt() {
        let msg = Msg::Confirm { ok: true, reason: wire::REASON_OK, attempt: 3 };
        let bytes = msg.to_bytes();
        // Every strict prefix is "incomplete", never "corrupt".
        for cut in 0..bytes.len() {
            assert_eq!(frame_extent(&bytes[..cut]), Ok(None), "prefix of {cut} bytes");
        }
        // The full frame (and the full frame plus the next frame's first bytes) reports
        // exactly the first frame's extent.
        assert_eq!(frame_extent(&bytes), Ok(Some(bytes.len())));
        let mut two = bytes.clone();
        two.extend_from_slice(&bytes[..3]);
        assert_eq!(frame_extent(&two), Ok(Some(bytes.len())));
        // Adversarial headers fail closed before any allocation.
        let mut huge = vec![3u8];
        put_varint(&mut huge, (wire::MAX_FRAME_BYTES as u64) + 1);
        assert!(frame_extent(&huge).is_err(), "over-cap length must be corrupt");
        let overflow = [3u8, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80];
        assert!(frame_extent(&overflow).is_err(), "varint overflow must be corrupt");
    }

    #[test]
    fn read_frame_rejects_truncated_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // Claims 16 body bytes, delivers 3, then closes.
            let mut frame = vec![3u8];
            put_varint(&mut frame, 16);
            frame.extend_from_slice(&[1, 2, 3]);
            s.write_all(&frame).unwrap();
        });
        let (mut stream, _) = listener.accept().unwrap();
        assert!(read_frame(&mut stream).is_err());
        writer.join().unwrap();
    }
}
