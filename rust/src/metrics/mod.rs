//! Communication accounting, experiment statistics, and a tiny benchmark harness.
//!
//! The paper's primary metric is the *total number of bytes transmitted in all rounds*
//! (§7.1). Every protocol implementation in this repo routes its messages through a
//! [`CommLog`], so reported costs are actual framed bytes — not analytic estimates.
//!
//! The harness also persists a **machine-readable perf trajectory**: every self-harnessed
//! bench target (`cargo bench --bench <name> -- --json [--smoke]`) appends its
//! [`BenchResult`]s as JSON records to a root-level trajectory file
//! ([`BENCH_DECODE_JSON`] for the decode microbenches, [`BENCH_ENCODE_JSON`] for the
//! encode-side microbenches, [`BENCH_PROTOCOL_JSON`] for the protocol-level sweeps,
//! [`BENCH_SERVER_JSON`] for the multi-client server operating points), so regressions
//! show up as data instead of anecdotes — CI runs the `--smoke` profile on every push
//! and uploads the files as artifacts.

use crate::hash::hash_u64;
use std::time::{Duration, Instant};

/// What stage of the protocol a wire frame belongs to. Every frame maps to exactly one
/// phase, so per-phase byte breakdowns are derived from the log instead of ad-hoc string
/// matching on labels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Parameter/estimator negotiation: `EstHello` and `Hello` frames.
    Handshake,
    /// The initiator's compressed CS sketch.
    Sketch,
    /// Ping-pong `Round` frames (residue + SMF + inquiries).
    Residue,
    /// End-of-attempt `Confirm` frames (success/failure + escalation bookkeeping).
    Confirm,
}

impl Phase {
    pub const ALL: [Phase; 4] = [Phase::Handshake, Phase::Sketch, Phase::Residue, Phase::Confirm];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Handshake => "handshake",
            Phase::Sketch => "sketch",
            Phase::Residue => "residue",
            Phase::Confirm => "confirm",
        }
    }

    /// Whether frames in this phase carry protocol payload (they count as "rounds" in
    /// the paper's sense); handshake headers and verdicts do not.
    pub fn is_payload(self) -> bool {
        matches!(self, Phase::Sketch | Phase::Residue)
    }
}

/// Per-session communication log: every message's direction, phase, and size.
#[derive(Clone, Debug, Default)]
pub struct CommLog {
    pub entries: Vec<CommEntry>,
}

#[derive(Clone, Debug)]
pub struct CommEntry {
    /// `true` when Alice → Bob.
    pub from_alice: bool,
    /// Which protocol stage the frame belongs to.
    pub phase: Phase,
    /// Framed bytes actually sent (the encoded size under the negotiated codec).
    pub bytes: usize,
    /// Framed bytes the same message would occupy with the columnar codec off
    /// (`Msg::raw_wire_len`). Equal to `bytes` for codec-off frames, so
    /// `raw_bytes − bytes` is the measured per-frame compression win.
    pub raw_bytes: usize,
}

impl CommLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, from_alice: bool, phase: Phase, bytes: usize) {
        self.entries.push(CommEntry { from_alice, phase, bytes, raw_bytes: bytes });
    }

    /// Like [`CommLog::record`], but with separate encoded and codec-off-equivalent
    /// sizes — the entry point for codec-aware frame accounting.
    pub fn record_framed(&mut self, from_alice: bool, phase: Phase, bytes: usize, raw: usize) {
        self.entries.push(CommEntry { from_alice, phase, bytes, raw_bytes: raw });
    }

    /// Total bytes in both directions — the paper's communication cost.
    pub fn total_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// Total codec-off-equivalent bytes — what [`CommLog::total_bytes`] would have been
    /// with the columnar codec disabled.
    pub fn total_raw_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.raw_bytes).sum()
    }

    /// Aggregate encoded/raw ratio (< 1.0 when the codec saved bytes; 1.0 for an empty
    /// or fully codec-off log).
    pub fn compression_ratio(&self) -> f64 {
        let raw = self.total_raw_bytes();
        if raw == 0 {
            1.0
        } else {
            self.total_bytes() as f64 / raw as f64
        }
    }

    /// Number of messages (the paper counts "rounds of communication" as messages sent,
    /// e.g. IBLT's bidirectional protocol is "two rounds").
    pub fn rounds(&self) -> usize {
        self.entries.len()
    }

    /// Bytes of every frame in the given phase, both directions.
    pub fn bytes_by_phase(&self, phase: Phase) -> usize {
        self.entries.iter().filter(|e| e.phase == phase).map(|e| e.bytes).sum()
    }

    /// Bytes in one direction (`from_alice`) of one phase.
    pub fn direction_phase_bytes(&self, from_alice: bool, phase: Phase) -> usize {
        self.entries
            .iter()
            .filter(|e| e.from_alice == from_alice && e.phase == phase)
            .map(|e| e.bytes)
            .sum()
    }

    /// Append every entry of `other` (partition/attempt aggregation).
    pub fn extend(&mut self, other: &CommLog) {
        self.entries.extend(other.entries.iter().cloned());
    }

    /// Payload frames (sketch + residue phases) — the paper-style round count of the
    /// conversation this log records.
    pub fn payload_frames(&self) -> usize {
        self.entries.iter().filter(|e| e.phase.is_payload()).count()
    }
}

/// Streaming mean/min/max/stddev accumulator for experiment tables.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: usize,
    sum: f64,
    sum_sq: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// **Population** standard deviation: √(E[v²] − E[v]²) with divisor `n`, not the
    /// sample estimator's `n − 1`. Experiment tables report the spread of the runs that
    /// actually happened rather than inferring a wider population, so repeated pushes of
    /// the same value always give 0. Returns `0.0` (never NaN) for fewer than two
    /// samples, and the inner `max(0.0)` absorbs the tiny negative residue the two-pass
    /// formula can leave behind under floating-point cancellation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let mean = self.mean();
        ((self.sum_sq / self.n as f64 - mean * mean).max(0.0)).sqrt()
    }
}

/// Minimal criterion-style micro-benchmark: warmup, then timed iterations with
/// mean/min reporting. (The image has no criterion crate; `cargo bench` targets use this.
/// See DESIGN.md §4 substitutions.)
pub struct Bench {
    pub name: String,
    warmup: Duration,
    measure: Duration,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
        }
    }

    pub fn with_times(mut self, warmup_ms: u64, measure_ms: u64) -> Self {
        self.warmup = Duration::from_millis(warmup_ms);
        self.measure = Duration::from_millis(measure_ms);
        self
    }

    /// Run `f` repeatedly; returns mean/min/p50/p99 over the timed iterations and
    /// prints a criterion-like line.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        // Measure.
        let mut times = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure || times.len() < 5 {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
            if times.len() > 100_000 {
                break;
            }
        }
        let total: Duration = times.iter().sum();
        let mean = total / times.len() as u32;
        times.sort_unstable();
        // Nearest-rank quantile over the sorted per-iteration times.
        let quantile = |q: f64| times[((times.len() - 1) as f64 * q).round() as usize];
        let result = BenchResult {
            name: self.name.clone(),
            mean,
            min: times[0],
            p50: quantile(0.5),
            p99: quantile(0.99),
            iters: times.len() as u64,
        };
        println!(
            "bench {:<48} mean {:>12?} min {:>12?} p50 {:>12?} p99 {:>12?} iters {} (warmup {})",
            result.name, result.mean, result.min, result.p50, result.p99, result.iters, warm_iters
        );
        result
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean: Duration,
    pub min: Duration,
    /// Median measured iteration time (nearest rank on the sorted samples).
    pub p50: Duration,
    /// 99th-percentile measured iteration time (nearest rank on the sorted samples).
    pub p99: Duration,
    pub iters: u64,
}

impl BenchResult {
    /// One flat JSON record: `name`, `mean_ns`, `min_ns`, `p50_ns`, `p99_ns`, `iters`,
    /// the run's config fingerprint, and a unix timestamp — the schema of the
    /// `BENCH_*.json` trajectory.
    pub fn to_json(&self, config_fingerprint: u64, unix_time_s: u64) -> String {
        format!(
            "{{\"name\":\"{}\",\"mean_ns\":{},\"min_ns\":{},\"p50_ns\":{},\"p99_ns\":{},\
             \"iters\":{},\"config_fingerprint\":\"{:#018x}\",\"unix_time_s\":{}}}",
            json_escape(&self.name),
            self.mean.as_nanos(),
            self.min.as_nanos(),
            self.p50.as_nanos(),
            self.p99.as_nanos(),
            self.iters,
            config_fingerprint,
            unix_time_s
        )
    }
}

/// Minimal JSON string escaping (bench names are ASCII-ish, but stay correct anyway).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Trajectory file for the decode microbench target (`decode_throughput`),
/// repo-root relative.
pub const BENCH_DECODE_JSON: &str = "BENCH_decode.json";

/// Trajectory file for the encode-side microbench target (`encode_throughput`:
/// serial vs parallel `Sketch::encode` at n = 100000, sketch-store hit vs miss,
/// streaming updates, codecs), repo-root relative.
pub const BENCH_ENCODE_JSON: &str = "BENCH_encode.json";

/// Trajectory file for the protocol-level bench targets
/// (`fig2a_unidirectional`, `fig2b_bidirectional`, `table2_ethereum`), repo-root relative.
pub const BENCH_PROTOCOL_JSON: &str = "BENCH_protocol.json";

/// Trajectory file for the multi-client server bench (`server_throughput`: sessions/sec
/// at clients = {1, 8, 32}, decoder pool on vs off), repo-root relative.
pub const BENCH_SERVER_JSON: &str = "BENCH_server.json";

/// Shared CLI profile of the self-harnessed bench targets:
/// `cargo bench --bench <name> -- [--json] [--smoke]`.
///
/// `--json` appends the run's results to the target's `BENCH_*.json` trajectory;
/// `--smoke` shrinks measurement windows and sweep sizes to CI scale (the smoke profile
/// keeps the headline configurations — e.g. `mp_build n=100000 d=1000` — so the CI
/// artifact still tracks the numbers that matter).
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchProfile {
    pub json: bool,
    pub smoke: bool,
}

impl BenchProfile {
    pub fn from_env_args() -> Self {
        let mut p = BenchProfile::default();
        for arg in std::env::args() {
            match arg.as_str() {
                "--json" => p.json = true,
                "--smoke" => p.smoke = true,
                _ => {}
            }
        }
        p
    }

    /// Scale a `(warmup_ms, measure_ms)` pair down for the smoke profile.
    pub fn times(&self, warmup_ms: u64, measure_ms: u64) -> (u64, u64) {
        if self.smoke {
            ((warmup_ms / 10).max(10), (measure_ms / 10).max(60))
        } else {
            (warmup_ms, measure_ms)
        }
    }

    /// Fingerprint of this run's configuration (bench target + profile), recorded on
    /// every JSON record so trajectory points from different profiles never get compared
    /// apples-to-oranges.
    pub fn fingerprint(&self, bench_target: &str) -> u64 {
        let mut h = 0xbe9c_0f17u64;
        for &b in bench_target.as_bytes() {
            h = hash_u64(h ^ b as u64, 0xbe9c_0001);
        }
        hash_u64(h ^ self.smoke as u64, 0xbe9c_0002)
    }
}

/// Append `results` to the JSON-array trajectory file at `path`, creating it on first
/// use. The file stays one valid JSON array across appends without needing a JSON
/// parser: the closing bracket is stripped, records are appended, and the bracket is
/// restored. A file that does not end in `]` (missing or corrupt) is started fresh.
pub fn append_bench_json(
    path: &str,
    results: &[BenchResult],
    config_fingerprint: u64,
) -> std::io::Result<()> {
    if results.is_empty() {
        return Ok(());
    }
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let body = results
        .iter()
        .map(|r| format!("  {}", r.to_json(config_fingerprint, now)))
        .collect::<Vec<_>>()
        .join(",\n");
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let content = match existing.trim_end().strip_suffix(']') {
        Some(head) => {
            let head = head.trim_end();
            if head.ends_with('[') {
                // Existing but empty array.
                format!("{head}\n{body}\n]\n")
            } else {
                format!("{head},\n{body}\n]\n")
            }
        }
        None => format!("[\n{body}\n]\n"),
    };
    std::fs::write(path, content)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_log_accounting() {
        let mut log = CommLog::new();
        log.record(true, Phase::Sketch, 100);
        log.record(false, Phase::Residue, 50);
        log.record(true, Phase::Residue, 10);
        assert_eq!(log.total_bytes(), 160);
        assert_eq!(log.rounds(), 3);
        assert_eq!(log.bytes_by_phase(Phase::Sketch), 100);
        assert_eq!(log.bytes_by_phase(Phase::Confirm), 0);
        assert_eq!(log.direction_phase_bytes(true, Phase::Residue), 10);
        assert_eq!(log.direction_phase_bytes(false, Phase::Residue), 50);
        // Phase totals partition the log: summing over Phase::ALL recovers the total.
        let by_phase: usize = Phase::ALL.iter().map(|&p| log.bytes_by_phase(p)).sum();
        assert_eq!(by_phase, log.total_bytes());
        let mut merged = CommLog::new();
        merged.extend(&log);
        merged.extend(&log);
        assert_eq!(merged.total_bytes(), 320);
    }

    #[test]
    fn comm_log_raw_vs_encoded_accounting() {
        let mut log = CommLog::new();
        // Plain `record` charges raw == encoded (codec-off frames).
        log.record(true, Phase::Handshake, 100);
        assert_eq!(log.total_raw_bytes(), 100);
        assert!((log.compression_ratio() - 1.0).abs() < 1e-12);
        // Codec frames charge both sides; the ratio reflects the measured saving.
        log.record_framed(true, Phase::Sketch, 60, 100);
        log.record_framed(false, Phase::Residue, 40, 100);
        assert_eq!(log.total_bytes(), 200);
        assert_eq!(log.total_raw_bytes(), 300);
        assert!((log.compression_ratio() - 200.0 / 300.0).abs() < 1e-12);
        // `extend` carries raw bytes across merges.
        let mut merged = CommLog::new();
        merged.extend(&log);
        assert_eq!(merged.total_raw_bytes(), 300);
        // Empty log: ratio defined as 1.0.
        assert!((CommLog::new().compression_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = Stats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.stddev() - (1.25f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn stddev_is_population_not_sample_and_never_nan() {
        // For [1, 2, 3, 4]: population variance = 1.25 (divisor n = 4); the sample
        // estimator would give 5/3 (divisor n − 1 = 3). Pin the population formula.
        let mut s = Stats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert!((s.stddev() - 1.25f64.sqrt()).abs() < 1e-12);
        assert!((s.stddev() - (5.0f64 / 3.0).sqrt()).abs() > 0.1, "sample formula crept in");
        // Degenerate inputs: n = 0 and n = 1 are defined as 0.0, never NaN.
        assert_eq!(Stats::new().stddev(), 0.0);
        let mut one = Stats::new();
        one.push(42.0);
        assert_eq!(one.stddev(), 0.0);
        // Repeated identical values: exactly zero spread, no NaN from cancellation.
        let mut same = Stats::new();
        for _ in 0..1000 {
            same.push(0.1);
        }
        assert!(same.stddev().is_finite());
        assert!(same.stddev() < 1e-6);
    }

    #[test]
    fn bench_runs_quickly_in_tests() {
        let b = Bench::new("noop").with_times(1, 5);
        let r = b.run(|| 1 + 1);
        assert!(r.iters >= 5);
        // Quantiles come off the sorted samples, so ordering is structural.
        assert!(r.min <= r.p50 && r.p50 <= r.p99);
    }

    #[test]
    fn bench_result_serializes_flat_json() {
        let r = BenchResult {
            name: "mp_build n=100000 d=1000 threads=4".to_string(),
            mean: Duration::from_nanos(1234),
            min: Duration::from_nanos(1200),
            p50: Duration::from_nanos(1230),
            p99: Duration::from_nanos(1500),
            iters: 42,
        };
        let json = r.to_json(0xabcd, 1700000000);
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"name\":\"mp_build n=100000 d=1000 threads=4\""));
        assert!(json.contains("\"mean_ns\":1234"));
        assert!(json.contains("\"min_ns\":1200"));
        assert!(json.contains("\"p50_ns\":1230"));
        assert!(json.contains("\"p99_ns\":1500"));
        assert!(json.contains("\"iters\":42"));
        assert!(json.contains("\"config_fingerprint\":\"0x000000000000abcd\""));
        // Escaping keeps hostile names inside the string literal.
        let hostile = BenchResult {
            name: "a\"b\\c\nd".to_string(),
            mean: Duration::ZERO,
            min: Duration::ZERO,
            p50: Duration::ZERO,
            p99: Duration::ZERO,
            iters: 1,
        };
        assert!(hostile.to_json(1, 1).contains("a\\\"b\\\\c\\u000ad"));
    }

    #[test]
    fn append_bench_json_keeps_one_valid_array_across_runs() {
        let path = std::env::temp_dir().join(format!(
            "commonsense_bench_trajectory_{}.json",
            std::process::id()
        ));
        let path = path.to_str().expect("temp path utf-8").to_string();
        let _ = std::fs::remove_file(&path);
        let mk = |name: &str| BenchResult {
            name: name.to_string(),
            mean: Duration::from_nanos(10),
            min: Duration::from_nanos(9),
            p50: Duration::from_nanos(10),
            p99: Duration::from_nanos(12),
            iters: 5,
        };
        append_bench_json(&path, &[mk("one"), mk("two")], 7).unwrap();
        append_bench_json(&path, &[mk("three")], 7).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        let trimmed = content.trim();
        assert!(trimmed.starts_with('['), "not an array: {trimmed}");
        assert!(trimmed.ends_with(']'), "unterminated array: {trimmed}");
        assert_eq!(content.matches("\"name\"").count(), 3, "append lost records");
        // Exactly n-1 record separators → still parseable as one array.
        assert_eq!(content.matches("},").count(), 2);
        let _ = std::fs::remove_file(&path);
    }
}
