//! Communication accounting, experiment statistics, and a tiny benchmark harness.
//!
//! The paper's primary metric is the *total number of bytes transmitted in all rounds*
//! (§7.1). Every protocol implementation in this repo routes its messages through a
//! [`CommLog`], so reported costs are actual framed bytes — not analytic estimates.

use std::time::{Duration, Instant};

/// What stage of the protocol a wire frame belongs to. Every frame maps to exactly one
/// phase, so per-phase byte breakdowns are derived from the log instead of ad-hoc string
/// matching on labels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Parameter/estimator negotiation: `EstHello` and `Hello` frames.
    Handshake,
    /// The initiator's compressed CS sketch.
    Sketch,
    /// Ping-pong `Round` frames (residue + SMF + inquiries).
    Residue,
    /// End-of-attempt `Confirm` frames (success/failure + escalation bookkeeping).
    Confirm,
}

impl Phase {
    pub const ALL: [Phase; 4] = [Phase::Handshake, Phase::Sketch, Phase::Residue, Phase::Confirm];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Handshake => "handshake",
            Phase::Sketch => "sketch",
            Phase::Residue => "residue",
            Phase::Confirm => "confirm",
        }
    }

    /// Whether frames in this phase carry protocol payload (they count as "rounds" in
    /// the paper's sense); handshake headers and verdicts do not.
    pub fn is_payload(self) -> bool {
        matches!(self, Phase::Sketch | Phase::Residue)
    }
}

/// Per-session communication log: every message's direction, phase, and size.
#[derive(Clone, Debug, Default)]
pub struct CommLog {
    pub entries: Vec<CommEntry>,
}

#[derive(Clone, Debug)]
pub struct CommEntry {
    /// `true` when Alice → Bob.
    pub from_alice: bool,
    /// Which protocol stage the frame belongs to.
    pub phase: Phase,
    pub bytes: usize,
}

impl CommLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, from_alice: bool, phase: Phase, bytes: usize) {
        self.entries.push(CommEntry { from_alice, phase, bytes });
    }

    /// Total bytes in both directions — the paper's communication cost.
    pub fn total_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// Number of messages (the paper counts "rounds of communication" as messages sent,
    /// e.g. IBLT's bidirectional protocol is "two rounds").
    pub fn rounds(&self) -> usize {
        self.entries.len()
    }

    /// Bytes of every frame in the given phase, both directions.
    pub fn bytes_by_phase(&self, phase: Phase) -> usize {
        self.entries.iter().filter(|e| e.phase == phase).map(|e| e.bytes).sum()
    }

    /// Bytes in one direction (`from_alice`) of one phase.
    pub fn direction_phase_bytes(&self, from_alice: bool, phase: Phase) -> usize {
        self.entries
            .iter()
            .filter(|e| e.from_alice == from_alice && e.phase == phase)
            .map(|e| e.bytes)
            .sum()
    }

    /// Append every entry of `other` (partition/attempt aggregation).
    pub fn extend(&mut self, other: &CommLog) {
        self.entries.extend(other.entries.iter().cloned());
    }

    /// Payload frames (sketch + residue phases) — the paper-style round count of the
    /// conversation this log records.
    pub fn payload_frames(&self) -> usize {
        self.entries.iter().filter(|e| e.phase.is_payload()).count()
    }
}

/// Streaming mean/min/max/stddev accumulator for experiment tables.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: usize,
    sum: f64,
    sum_sq: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let mean = self.mean();
        ((self.sum_sq / self.n as f64 - mean * mean).max(0.0)).sqrt()
    }
}

/// Minimal criterion-style micro-benchmark: warmup, then timed iterations with
/// mean/min reporting. (The image has no criterion crate; `cargo bench` targets use this.
/// See DESIGN.md §4 substitutions.)
pub struct Bench {
    pub name: String,
    warmup: Duration,
    measure: Duration,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
        }
    }

    pub fn with_times(mut self, warmup_ms: u64, measure_ms: u64) -> Self {
        self.warmup = Duration::from_millis(warmup_ms);
        self.measure = Duration::from_millis(measure_ms);
        self
    }

    /// Run `f` repeatedly; returns (mean, min, iters) and prints a criterion-like line.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        // Measure.
        let mut times = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure || times.len() < 5 {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
            if times.len() > 100_000 {
                break;
            }
        }
        let total: Duration = times.iter().sum();
        let mean = total / times.len() as u32;
        let min = *times.iter().min().unwrap();
        let result = BenchResult { name: self.name.clone(), mean, min, iters: times.len() as u64 };
        println!(
            "bench {:<48} mean {:>12?} min {:>12?} iters {} (warmup {})",
            result.name, result.mean, result.min, result.iters, warm_iters
        );
        result
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean: Duration,
    pub min: Duration,
    pub iters: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_log_accounting() {
        let mut log = CommLog::new();
        log.record(true, Phase::Sketch, 100);
        log.record(false, Phase::Residue, 50);
        log.record(true, Phase::Residue, 10);
        assert_eq!(log.total_bytes(), 160);
        assert_eq!(log.rounds(), 3);
        assert_eq!(log.bytes_by_phase(Phase::Sketch), 100);
        assert_eq!(log.bytes_by_phase(Phase::Confirm), 0);
        assert_eq!(log.direction_phase_bytes(true, Phase::Residue), 10);
        assert_eq!(log.direction_phase_bytes(false, Phase::Residue), 50);
        // Phase totals partition the log: summing over Phase::ALL recovers the total.
        let by_phase: usize = Phase::ALL.iter().map(|&p| log.bytes_by_phase(p)).sum();
        assert_eq!(by_phase, log.total_bytes());
        let mut merged = CommLog::new();
        merged.extend(&log);
        merged.extend(&log);
        assert_eq!(merged.total_bytes(), 320);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = Stats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.stddev() - (1.25f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_quickly_in_tests() {
        let b = Bench::new("noop").with_times(1, 5);
        let r = b.run(|| 1 + 1);
        assert!(r.iters >= 5);
    }
}
