//! Chaos suite: deterministic fault injection across the whole protocol surface.
//!
//! The headline is the seeded fault matrix — every [`FaultKind`] × every protocol
//! [`Phase`] × four set shapes (subset, overlap, disjoint-heavy, fully disjoint) ×
//! codec on/off — with one invariant: **a faulted run terminates within its deadline
//! and either returns the exactly-correct intersection or a typed [`SetxError`] —
//! never a panic, never a wrong answer.** On top of that, the retry layer must
//! converge to the correct answer whenever the fault plan leaves a fault-free attempt,
//! and the server must absorb wire garbage, duplicated frames, and faulty multi-party
//! spokes without poisoning tenant state or leaking admission slots.
//!
//! Everything is seeded (`FaultPlan` coins, workloads, retry jitter), so a failure
//! here reproduces bit-for-bit on re-run.

use commonsense::data::synth;
use commonsense::metrics::Phase;
use commonsense::server::loadgen::{self, LoadgenConfig};
use commonsense::server::SetxServer;
use commonsense::setx::multi::net::join_round;
use commonsense::setx::multi::Party;
use commonsense::setx::transport::{mem_pair, FaultInjector, FaultKind, FaultPlan, TcpTransport};
use commonsense::setx::{RetryPolicy, Setx, SetxError, SetxReport};
use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

const ALL_KINDS: [FaultKind; 5] = [
    FaultKind::DropConnection,
    FaultKind::TruncateFrame,
    FaultKind::FlipBytes,
    FaultKind::Delay,
    FaultKind::DuplicateFrame,
];

/// Per-cell deadline. Generous for CI — a healthy cell finishes in milliseconds; the
/// point is that *no* fault combination can wedge a run indefinitely.
const CELL_DEADLINE: Duration = Duration::from_secs(60);

/// The four workload shapes of the matrix. "disjoint_heavy" mirrors the integration
/// fleet's third shape (most of each set unique); "disjoint" is the degenerate
/// zero-intersection case, where the difference *is* the union.
fn shapes() -> Vec<(&'static str, Vec<u64>, Vec<u64>)> {
    let (sub_a, sub_b) = synth::subset_pair(900, 60, 0xC1);
    let (ov_a, ov_b) = synth::overlap_pair(800, 45, 55, 0xC2);
    let (dh_a, dh_b) = synth::overlap_pair(80, 140, 160, 0xC3);
    let (dj_a, dj_b) = synth::overlap_pair(0, 90, 110, 0xC4);
    vec![
        ("subset", sub_a, sub_b),
        ("overlap", ov_a, ov_b),
        ("disjoint_heavy", dh_a, dh_b),
        ("disjoint", dj_a, dj_b),
    ]
}

/// One matrix cell: Alice runs over a fault-wrapped in-memory transport against a Bob
/// thread, under a wall-clock deadline. The peer thread must never panic; dropping the
/// faulted client end closes the channel, so Bob always unblocks (`Ok(None)` →
/// `PeerClosed`), which is the termination argument for the whole matrix.
fn run_cell(
    label: &str,
    a: &[u64],
    b: &[u64],
    codec: bool,
    injector: &FaultInjector,
) -> Result<SetxReport, SetxError> {
    let alice = Setx::builder(a).seed(0xC4A05).codec(codec).build().unwrap();
    let bob = Setx::builder(b).seed(0xC4A05).codec(codec).build().unwrap();
    let (client_end, server_end) = mem_pair();
    let peer = std::thread::spawn(move || {
        let mut t = server_end;
        let _ = bob.run(&mut t);
    });
    let started = Instant::now();
    let mut transport = injector.wrap(client_end);
    let result = alice.run(&mut transport);
    drop(transport);
    peer.join().unwrap_or_else(|_| panic!("{label}: peer endpoint panicked"));
    assert!(
        started.elapsed() < CELL_DEADLINE,
        "{label}: run exceeded the {CELL_DEADLINE:?} deadline"
    );
    result
}

/// The matrix itself: 5 kinds × 4 phases × 4 shapes × codec on/off, every cell
/// targeting the first frame of its phase. A cell whose phase never occurs on that
/// shape's wire path simply runs clean — in which case the answer must be exact.
#[test]
fn fault_matrix_terminates_correct_or_typed_never_wrong() {
    for (shape, a, b) in shapes() {
        let expected = synth::intersect(&a, &b);
        for codec in [false, true] {
            // Fault-free baseline first: the cell runner itself must be sound.
            let clean = FaultPlan::new(1).injector();
            let label = format!("{shape}/codec={codec}/baseline");
            let report = run_cell(&label, &a, &b, codec, &clean)
                .unwrap_or_else(|e| panic!("{label}: clean run failed: {e}"));
            assert_eq!(report.intersection, expected, "{label}");
            assert_eq!(clean.fired(), 0, "{label}: empty plan must fire nothing");

            for kind in ALL_KINDS {
                for phase in Phase::ALL {
                    let label = format!("{shape}/codec={codec}/{}/{phase:?}", kind.name());
                    let injector = match kind {
                        FaultKind::Delay => {
                            FaultPlan::new(0xFA57).delay_nth(Some(phase), 1, 250_000)
                        }
                        _ => FaultPlan::new(0xFA57).fail_nth(kind, Some(phase), 1),
                    }
                    .injector();
                    match run_cell(&label, &a, &b, codec, &injector) {
                        Ok(report) => {
                            // A survivable fault (delay, duplicate, trailing-frame
                            // loss) must still produce the exact answer.
                            assert_eq!(report.intersection, expected, "{label}");
                        }
                        Err(err) => {
                            // Typed and printable — and the transient classification
                            // must hold: wire damage the client *parsed* is fatal,
                            // everything connection-shaped is retryable.
                            let rendered = err.to_string();
                            assert!(!rendered.is_empty(), "{label}");
                            if matches!(err, SetxError::MalformedFrame(_)) {
                                assert!(!err.is_transient(), "{label}: {rendered}");
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Delay faults are simulated time, not real time: a cell with quarter-millisecond
/// injected delays on every phase completes at memory speed and stays exact.
#[test]
fn delays_everywhere_are_simulated_not_slept() {
    let (a, b) = synth::overlap_pair(700, 30, 35, 0xDE1);
    let expected = synth::intersect(&a, &b);
    let mut plan = FaultPlan::new(5);
    for phase in Phase::ALL {
        plan = plan.delay_nth(Some(phase), 1, 50_000_000); // 50 simulated ms each
    }
    let injector = plan.injector();
    let started = Instant::now();
    let report = run_cell("delay-everywhere", &a, &b, false, &injector).unwrap();
    assert_eq!(report.intersection, expected);
    assert!(injector.fired() >= 2, "at least handshake + sketch delays must fire");
    assert!(
        started.elapsed() < Duration::from_secs(5),
        "simulated delay must not consume wall-clock"
    );
}

/// Retry convergence across the matrix: for every transient fault kind and every
/// phase, an `nth = 1` rule fires exactly once on the shared injector, so the second
/// attempt is guaranteed clean — `run_with_retry` must land on the exact answer with
/// at most one retry, whatever the phase of the damage.
#[test]
fn retry_converges_whenever_the_plan_leaves_a_clean_attempt() {
    let (a, b) = synth::overlap_pair(800, 45, 55, 0x9E);
    let expected = synth::intersect(&a, &b);
    let alice = Setx::builder(&a).seed(3).build().unwrap();
    let bob = std::sync::Arc::new(Setx::builder(&b).seed(3).build().unwrap());
    // Zero-wait schedule: chaos tests never sleep.
    let policy = RetryPolicy { max_retries: 2, base_ms: 0, cap_ms: 0, jitter_seed: 1 };
    for kind in [FaultKind::DropConnection, FaultKind::TruncateFrame] {
        for phase in Phase::ALL {
            let label = format!("retry/{}/{phase:?}", kind.name());
            let injector = FaultPlan::new(0xBEE).fail_nth(kind, Some(phase), 1).injector();
            let mut peers = Vec::new();
            let result = alice.run_with_retry_observed(
                &policy,
                0,
                |_attempt| {
                    let (client_end, server_end) = mem_pair();
                    let bob = std::sync::Arc::clone(&bob);
                    peers.push(std::thread::spawn(move || {
                        let mut t = server_end;
                        let _ = bob.run(&mut t);
                    }));
                    Ok(injector.wrap(client_end))
                },
                |err, _backoff| assert!(err.is_transient(), "{label}: retried a fatal error"),
            );
            for p in peers {
                p.join().unwrap_or_else(|_| panic!("{label}: peer panicked"));
            }
            let report = result.unwrap_or_else(|e| panic!("{label}: did not converge: {e}"));
            assert_eq!(report.intersection, expected, "{label}");
            assert!(report.retries <= 1, "{label}: one nth-rule costs at most one retry");
            if report.retries == 1 {
                assert!(report.retry_bytes > 0 || injector.fired() == 1, "{label}");
            }
        }
    }
}

/// Raw wire garbage at the server: an unterminated length varint can never become a
/// frame, so the connection dies pre-routing with a typed `MalformedFrame` — counted
/// as an *unrouted* protocol fault, the slot freed, and the next clean client served.
#[test]
fn server_counts_wire_garbage_as_an_unrouted_protocol_fault() {
    let host: Vec<u64> = (0..1_500).collect();
    let server = SetxServer::builder(Setx::builder(&host).build().unwrap())
        .workers(1)
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    let mut garbage = TcpStream::connect(addr).unwrap();
    // Frame type byte, then ten continuation bytes: the length varint overflows u64.
    garbage.write_all(&[0x01, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF]).unwrap();
    garbage.flush().unwrap();
    wait_until("the garbage connection to be dropped", || {
        let s = server.stats();
        s.protocol_faults == 1 && s.inflight == 0
    });
    drop(garbage);

    // The slot is free and tenant state untouched: a real client is served.
    let client: Vec<u64> = (0..1_000).collect();
    let alice = Setx::builder(&client).build().unwrap();
    let report = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
    assert_eq!(report.intersection, client);
    wait_until("the clean session to be counted", || server.stats().sessions_served == 1);

    let stats = server.shutdown();
    assert_eq!(stats.protocol_faults, 1, "{stats:?}");
    assert_eq!(stats.unrouted_protocol_faults, 1, "pre-routing garbage has no tenant");
    assert_eq!(stats.unrouted_failed, 1, "{stats:?}");
    assert!(stats.protocol_faults <= stats.sessions_failed, "{stats:?}");
    // Shard exactness holds with faults in the mix.
    let tenant_faults: u64 = stats.tenants.iter().map(|t| t.protocol_faults).sum();
    assert_eq!(tenant_faults + stats.unrouted_protocol_faults, stats.protocol_faults);
}

/// A duplicated handshake frame *after* routing: the server's endpoint rejects the
/// replay with a typed protocol error on the tenant's shard — and the tenant keeps
/// serving clean clients afterwards (no decoder-pool or sketch-store poisoning).
#[test]
fn server_counts_a_replayed_hello_on_the_tenant_shard() {
    let host: Vec<u64> = (0..1_500).collect();
    let server = SetxServer::builder(Setx::builder(&host).build().unwrap())
        .workers(1)
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    let client: Vec<u64> = (0..1_200).collect();
    let alice = Setx::builder(&client).build().unwrap();
    let injector = FaultPlan::new(3)
        .fail_nth(FaultKind::DuplicateFrame, Some(Phase::Handshake), 1)
        .injector();
    let mut transport = injector.wrap(TcpTransport::connect(addr).unwrap());
    let err = alice.run(&mut transport).unwrap_err();
    drop(transport);
    assert_eq!(injector.log().count(FaultKind::DuplicateFrame), 1);
    // The client sees its connection die (transient), not a protocol error of its own.
    assert!(err.is_transient(), "client-side error must be retryable, got {err}");

    wait_until("the replay to be counted on the tenant shard", || {
        server.stats().protocol_faults == 1
    });

    // Same tenant, clean client: the shard still serves.
    let clean = Setx::builder(&client).build().unwrap();
    let report = clean.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
    assert_eq!(report.intersection, client);
    wait_until("the clean session to be counted", || server.stats().sessions_served == 1);

    let stats = server.shutdown();
    assert_eq!(stats.protocol_faults, 1, "{stats:?}");
    assert_eq!(stats.unrouted_protocol_faults, 0, "the replay happened after routing");
    let shard = &stats.tenants[0];
    assert_eq!(shard.protocol_faults, 1, "{stats:?}");
    assert_eq!(shard.sessions_failed, 1, "{stats:?}");
    assert_eq!(shard.sessions_served, 1, "{stats:?}");
    assert!(stats.protocol_faults <= stats.sessions_failed, "{stats:?}");
}

/// A multi-party round with one fault-injected spoke: the spoke's connection drops
/// mid-round, the coordinator isolates it, and the surviving spokes land on the exact
/// intersection of the parties that stayed.
#[test]
fn multi_party_round_survives_a_faulty_spoke() {
    let sets = synth::overlap_n(4, 500, 12, 0xFA11);
    let host0: Vec<u64> = (0..600).collect();
    let server = SetxServer::builder(Setx::builder(&host0).build().unwrap())
        .workers(2)
        .multi_tenant(6, sets[0].clone(), 4)
        .timeouts(Some(Duration::from_millis(500)), Some(Duration::from_millis(500)))
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    // Spoke 3's transport drops on its 3rd frame — after its join hello is on the
    // wire, before the round completes.
    let faulty_set = sets[3].clone();
    let faulty = std::thread::spawn(move || {
        let cfg = *Setx::builder(&faulty_set).namespace(6).build().unwrap().config();
        let mut party = Party::new(&cfg, faulty_set, 3, 4).unwrap();
        let injector = FaultPlan::new(9).fail_nth(FaultKind::DropConnection, None, 3).injector();
        let mut t = injector.wrap(TcpTransport::connect(addr).unwrap());
        party.run(&mut t)
    });
    let live: Vec<_> = (1u32..3)
        .map(|id| {
            let set = sets[id as usize].clone();
            std::thread::spawn(move || {
                let cfg = *Setx::builder(&set).namespace(6).build().unwrap().config();
                join_round(addr, &cfg, set, id, 4)
            })
        })
        .collect();

    let expected = {
        let mut acc = sets[0].clone();
        for s in &sets[1..3] {
            acc = synth::intersect(&acc, s);
        }
        acc
    };
    for (i, h) in live.into_iter().enumerate() {
        let r = h.join().expect("spoke thread").expect("live spoke completes");
        assert_eq!(r.intersection, expected, "spoke {} answer", i + 1);
    }
    let spoke_err = faulty.join().expect("faulty spoke thread");
    assert!(spoke_err.is_err(), "the faulted spoke must surface a typed error");

    let mut reports = Vec::new();
    wait_until("the degraded round to be drained", || {
        reports.extend(server.take_multi_reports(6));
        !reports.is_empty()
    });
    let round = &reports[0];
    assert_eq!(round.intersection, expected, "the round excludes the dropped spoke");
    assert_eq!(round.completed(), 2);
    if let Some(dropped) = round.parties.iter().find(|p| p.party == 3) {
        assert!(dropped.error.is_some(), "the dropped spoke must carry its error");
    }
    let stats = server.shutdown();
    assert_eq!(stats.sessions_served, 2, "{stats:?}");
    assert_eq!(stats.sessions_failed, 1, "the dropped spoke: {stats:?}");
}

/// The acceptance criterion: a fleet under a 25% per-attempt injected-disconnect rate
/// still reaches 100% verified success — every drop absorbed by the retry layer, the
/// cost visible in `retries`, and nobody exhausting the budget. Seed 7's coin
/// sequence injects 10 drops with a worst streak of 3 (budget 6), precomputed from
/// the same `split_mix64` the generator uses.
#[test]
fn fleet_fully_succeeds_under_injected_disconnects() {
    let cfg = LoadgenConfig {
        clients: 6,
        rounds: 3,
        common: 2_000,
        client_unique: 40,
        server_unique: 60,
        seed: 7,
        busy_retries: 6,
        disconnect_rate: 0.25,
        ..LoadgenConfig::default()
    };
    let (host, _clients, _expected) = cfg.workload();
    let server = SetxServer::builder(cfg.endpoint(&host).unwrap())
        .workers(2)
        .bind("127.0.0.1:0")
        .unwrap();
    let report = loadgen::run(server.local_addr(), &cfg);
    assert!(report.verified(), "failures: {:?}", report.failures);
    assert_eq!(report.sessions_ok, 18);
    assert_eq!(report.gave_up, 0, "no session may exhaust the budget at this rate");
    assert!(report.retries >= 10, "seed 7 injects 10 drops, got {}", report.retries);
    let stats = server.shutdown();
    // Server-side, injected client drops are failed sessions — but never protocol
    // faults, and never wedged slots.
    assert_eq!(stats.inflight, 0, "{stats:?}");
    assert_eq!(stats.protocol_faults, 0, "{stats:?}");
    assert_eq!(stats.sessions_served, 18, "{stats:?}");
}

/// Poll `cond` until it holds or a 10 s deadline passes.
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}
