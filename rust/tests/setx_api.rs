//! The front-door contract: one builder config drives every transport to the same
//! answer with the same accounting; failures are typed; transcripts are deterministic;
//! byte accounting is wire-true.

use commonsense::coordinator::{connect, serve};
use commonsense::data::synth;
use commonsense::metrics::Phase;
use commonsense::setx::transport::{mem_pair, TcpTransport};
use commonsense::setx::{parallel, DiffSize, Mode, ProtocolKind, Setx, SetxError};
use std::net::TcpListener;

/// **Acceptance**: the identical builder config (Auto mode, estimated diff size — no
/// caller-supplied d anywhere) runs over in-memory, TCP, and the partitioned pool, and
/// all three produce identical intersections; in-memory and TCP match byte-for-byte in
/// every phase and direction.
#[test]
fn one_builder_config_drives_all_three_transports() {
    let (a, b) = synth::overlap_pair(4_000, 50, 70, 0x3a);
    let build = |set: &[u64]| {
        Setx::builder(set)
            .mode(Mode::Auto)
            .diff_size(DiffSize::Estimated)
            .seed(0xFACADE)
            .build()
            .unwrap()
    };
    let alice = build(&a);
    let bob = build(&b);

    // 1. In-memory.
    let (mem_a, mem_b) = alice.run_pair(&bob).unwrap();
    assert!(mem_a.converged && mem_b.converged);
    assert_eq!(mem_a.local_unique, synth::difference(&a, &b));
    assert_eq!(mem_b.local_unique, synth::difference(&b, &a));
    assert_eq!(mem_a.intersection, synth::intersect(&a, &b));
    assert_eq!(mem_a.intersection, mem_b.intersection);

    // 2. TCP.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let bob2 = bob.clone();
    let server = std::thread::spawn(move || serve(&listener, &bob2).unwrap());
    let tcp_a = connect(addr, &alice).unwrap();
    let tcp_b = server.join().unwrap();
    assert_eq!(tcp_a.intersection, mem_a.intersection);
    assert_eq!(tcp_b.local_unique, mem_b.local_unique);
    // Byte-identical per phase and direction: the transport cannot change the protocol.
    for phase in Phase::ALL {
        assert_eq!(tcp_a.phase_sent(phase), mem_a.phase_sent(phase), "{}", phase.name());
        assert_eq!(tcp_a.phase_received(phase), mem_a.phase_received(phase), "{}", phase.name());
        assert_eq!(tcp_b.phase_sent(phase), mem_b.phase_sent(phase), "{}", phase.name());
    }
    assert_eq!(tcp_a.total_bytes(), mem_a.total_bytes());

    // 3. Partitioned pool (same builder config, its own partition-level accounting).
    let par = parallel::run_partitioned(&alice, &bob, 8, 4).unwrap();
    assert_eq!(par.client.intersection, mem_a.intersection);
    assert_eq!(par.client.local_unique, mem_a.local_unique);
    assert_eq!(par.server.local_unique, mem_b.local_unique);
    assert!((1..=4).contains(&par.peak_workers));
    // Mirror + partition accounting stays coherent: directions conserve, phases sum.
    assert_eq!(par.client.bytes_sent(), par.server.bytes_received());
    assert_eq!(par.client.bytes_received(), par.server.bytes_sent());
    let phase_sum: usize = Phase::ALL.iter().map(|&p| par.client.phase_total(p)).sum();
    assert_eq!(phase_sum, par.client.total_bytes());
    // The global estimator handshake is charged (exactly once) there too.
    assert!(par.client.phase_sent(Phase::Handshake) > 0);
    assert!(mem_a.phase_sent(Phase::Handshake) > 0);
}

/// **Acceptance (codec negotiate-down)**: a codec-off endpoint completes against a
/// codec-on peer — the handshake turns the columnar codec off for the connection, every
/// frame is byte-identical to the pre-codec format (raw == sent on both transcripts),
/// and the answers match a codec-on/codec-on run of the same sets.
#[test]
fn mixed_codec_endpoints_negotiate_down_and_complete() {
    let (a, b) = synth::overlap_pair(6_000, 60, 80, 0x0dec);
    let on = |set: &[u64]| Setx::builder(set).seed(0xFACADE).build().unwrap();
    let off = |set: &[u64]| Setx::builder(set).seed(0xFACADE).codec(false).build().unwrap();

    // codec-on ↔ codec-on: the reference answers, with real savings.
    let (ra, rb) = on(&a).run_pair(&on(&b)).unwrap();
    assert_eq!(ra.local_unique, synth::difference(&a, &b));
    assert!(ra.total_bytes() < ra.total_raw_bytes(), "codec-on session must save bytes");
    assert!(ra.compression_ratio() < 1.0);

    // Both-off: the pre-codec reference wire (raw == sent on every frame).
    let (fa, _) = off(&a).run_pair(&off(&b)).unwrap();
    assert_eq!(fa.total_raw_bytes(), fa.total_bytes());
    assert_eq!(fa.intersection, ra.intersection);
    // The codec-on/codec-on raw accounting reproduces the codec-off wire exactly.
    assert_eq!(ra.total_raw_bytes(), fa.total_bytes());

    // Mixed, both orientations: negotiate down, identical answers. Every post-handshake
    // frame is byte-identical to the codec-off format; only the codec-on side's one
    // EstHello still carries its (smaller) columnar strata blob, so the raw accounting
    // reproduces the both-off wire exactly while the measured bytes come in under it.
    for (alice, bob) in [(on(&a), off(&b)), (off(&a), on(&b))] {
        let (ma, mb) = alice.run_pair(&bob).unwrap();
        assert_eq!(ma.intersection, ra.intersection);
        assert_eq!(ma.local_unique, ra.local_unique);
        assert_eq!(mb.local_unique, rb.local_unique);
        assert_eq!(ma.total_bytes(), mb.total_bytes(), "both ends log one conversation");
        assert_eq!(ma.total_raw_bytes(), fa.total_bytes(), "mixed raw == both-off wire");
        assert!(
            ma.total_bytes() < fa.total_bytes(),
            "the codec-on hello's columnar strata still shrink a mixed handshake"
        );
        // But the body of the conversation negotiated down: far less saved than on/on.
        assert!(fa.total_bytes() - ma.total_bytes() < ra.total_raw_bytes() - ra.total_bytes());
    }
}

/// **Satellite (wire-accounting truth)**: bytes observed on the socket — counted by the
/// transport, below the protocol — equal the endpoint's own `CommLog` totals, on both
/// peers, across workload shapes.
#[test]
fn tcp_socket_bytes_equal_commlog_totals() {
    for (au, bu, seed) in [(30usize, 40usize, 1u64), (0, 50, 2), (80, 20, 3)] {
        let (a, b) = synth::overlap_pair(2_500, au, bu, seed);
        let alice = Setx::builder(&a).build().unwrap();
        let bob = Setx::builder(&b).build().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let bob2 = bob.clone();
        let server = std::thread::spawn(move || {
            let mut transport = TcpTransport::accept(&listener).unwrap();
            let report = bob2.run(&mut transport).unwrap();
            (report, transport.bytes_sent, transport.bytes_received)
        });
        let mut transport = TcpTransport::connect(addr).unwrap();
        let ra = alice.run(&mut transport).unwrap();
        let (rb, b_sent, b_recv) = server.join().unwrap();
        // Socket ground truth == protocol self-accounting, per endpoint and direction.
        assert_eq!(transport.bytes_sent, ra.bytes_sent(), "client sent (seed {seed})");
        assert_eq!(transport.bytes_received, ra.bytes_received(), "client recv (seed {seed})");
        assert_eq!(b_sent, rb.bytes_sent(), "server sent (seed {seed})");
        assert_eq!(b_recv, rb.bytes_received(), "server recv (seed {seed})");
        // Conservation across the wire.
        assert_eq!(transport.bytes_sent, b_recv, "seed {seed}");
        assert_eq!(transport.bytes_received, b_sent, "seed {seed}");
        assert_eq!(ra.total_bytes(), rb.total_bytes(), "seed {seed}");
    }
}

/// **Satellite (determinism)**: identical sets, configs, and seeds produce byte-identical
/// transcripts, frame for frame, in both directions.
#[test]
fn identical_seeds_produce_byte_identical_transcripts() {
    fn transcripts() -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
        let (a, b) = synth::overlap_pair(3_000, 40, 50, 9);
        let alice = Setx::builder(&a).seed(0xD15C).build().unwrap();
        let bob = Setx::builder(&b).seed(0xD15C).build().unwrap();
        let (mut ta, mut tb) = mem_pair();
        let server = std::thread::spawn(move || {
            bob.run(&mut tb).unwrap();
            tb.sent_frames
        });
        alice.run(&mut ta).unwrap();
        let from_bob = server.join().unwrap();
        (ta.sent_frames, from_bob)
    }
    let (a1, b1) = transcripts();
    let (a2, b2) = transcripts();
    assert!(!a1.is_empty() && !b1.is_empty());
    assert_eq!(a1, a2, "client transcript must be byte-identical across runs");
    assert_eq!(b1, b2, "server transcript must be byte-identical across runs");
}

/// `DiffSize::Estimated` end to end: nobody supplies d, the handshake pays a few KB of
/// estimators (visible in the phase breakdown), and the answer is exact.
#[test]
fn estimated_diff_size_needs_no_caller_d() {
    let (a, b) = synth::overlap_pair(10_000, 120, 180, 0xe57);
    let alice = Setx::builder(&a).build().unwrap();
    let bob = Setx::builder(&b).build().unwrap();
    let (ra, rb) = alice.run_pair(&bob).unwrap();
    assert_eq!(ra.local_unique, synth::difference(&a, &b));
    assert_eq!(rb.local_unique, synth::difference(&b, &a));
    assert!(ra.phase_total(Phase::Handshake) > 0, "estimators ride the handshake");
    assert!(ra.phase_total(Phase::Confirm) > 0, "attempts end with verdicts");
    let phase_sum: usize = Phase::ALL.iter().map(|&p| ra.phase_total(p)).sum();
    assert_eq!(phase_sum, ra.total_bytes());
    // Both endpoints record the identical conversation.
    assert_eq!(ra.total_bytes(), rb.total_bytes());
    assert_eq!(ra.bytes_sent(), rb.bytes_received());
}

/// The escalation ladder: an endpoint configured with an under-calibrated safety factor
/// fails its first attempt(s) and recovers *within the same connection*, reporting how
/// many attempts it took — instead of failing opaquely.
#[test]
fn escalation_ladder_recovers_undersized_first_attempt() {
    let (a, b) = synth::overlap_pair(6_000, 150, 150, 0x1ad);
    let build = |set: &[u64]| {
        Setx::builder(set)
            .mode(Mode::Bidi)
            .safety(0.45)
            .max_attempts(4)
            .seed(3)
            .build()
            .unwrap()
    };
    let (ra, rb) = build(&a).run_pair(&build(&b)).unwrap();
    assert!(ra.attempts >= 2, "safety 0.45 must fail attempt 0 (attempts = {})", ra.attempts);
    assert_eq!(ra.attempts, rb.attempts, "both sides count attempts identically");
    assert_eq!(ra.local_unique, synth::difference(&a, &b));
    assert_eq!(rb.local_unique, synth::difference(&b, &a));
}

/// The unidirectional ladder: a starved one-shot decode reports failure via `Confirm`,
/// the sender escalates on the same connection, and the protocol stays unidirectional.
#[test]
fn uni_ladder_escalates_within_connection() {
    let (a, b) = synth::subset_pair(8_000, 200, 0x11);
    let build = |set: &[u64]| {
        Setx::builder(set).mode(Mode::Uni).safety(0.5).max_attempts(4).build().unwrap()
    };
    let (ra, rb) = build(&a).run_pair(&build(&b)).unwrap();
    assert!(rb.attempts >= 2, "safety 0.5 must fail attempt 0 (attempts = {})", rb.attempts);
    assert_eq!(rb.kind, ProtocolKind::Uni);
    assert_eq!(rb.local_unique, synth::difference(&b, &a));
    assert!(ra.local_unique.is_empty());
}

/// A forced unidirectional run on a genuinely two-sided difference cannot succeed: the
/// ladder exhausts and the caller gets the typed decode failure with the attempt count.
#[test]
fn forced_uni_on_two_sided_difference_fails_typed() {
    let (a, b) = synth::overlap_pair(3_000, 60, 60, 0x2b);
    let build = |set: &[u64]| {
        Setx::builder(set).mode(Mode::Uni).max_attempts(2).build().unwrap()
    };
    match build(&a).run_pair(&build(&b)) {
        Err(SetxError::Decode { attempts, .. }) => assert_eq!(attempts, 2),
        Err(other) => panic!("expected Decode, got {other}"),
        Ok((ra, _)) => panic!("two-sided uni must not succeed ({} uniques)", ra.local_unique.len()),
    }
}

/// `Mode::Auto` detects a subset workload from the directional Strata signal and runs
/// the cheaper one-message protocol — with no hints from the caller.
#[test]
fn auto_mode_detects_subset_and_runs_uni() {
    let (a, b) = synth::subset_pair(20_000, 250, 0xab);
    let alice = Setx::builder(&a).build().unwrap();
    let bob = Setx::builder(&b).build().unwrap();
    let (ra, rb) = alice.run_pair(&bob).unwrap();
    assert_eq!(rb.kind, ProtocolKind::Uni, "subset shape must route to unidirectional");
    assert_eq!(rb.local_unique, synth::difference(&b, &a));
    assert_eq!(ra.intersection, rb.intersection);
    // And a two-sided workload routes to the ping-pong.
    let (x, y) = synth::overlap_pair(10_000, 100, 100, 0xac);
    let ex = Setx::builder(&x).build().unwrap();
    let ey = Setx::builder(&y).build().unwrap();
    let (rx, _) = ex.run_pair(&ey).unwrap();
    assert_eq!(rx.kind, ProtocolKind::Bidi);
}
