//! Multi-party integration: N ∈ {3, 5, 8} parties of mixed shapes — subset, general
//! overlap, disjoint-heavy — all learning `∩ᵢSᵢ`, cross-checked against the *iterated
//! two-party fold* (`run_pair` over a running intersection: the reference any N-party
//! round must agree with), plus the per-party byte-accounting invariant and the
//! stalled-spoke drop over real sockets.
//!
//! Every listener binds `127.0.0.1:0` (an OS-assigned ephemeral port), so this suite is
//! safe at any `--test-threads` level.

use commonsense::data::synth;
use commonsense::hash::Xoshiro256;
use commonsense::setx::multi::net::{host_round, join_round};
use commonsense::setx::multi::{MultiError, Party};
use commonsense::setx::Setx;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// N sets of three interleaved shapes around one shared core: the coordinator holds the
/// full core plus its own small tail; spokes cycle through subset (a strict prefix of
/// the core, no tail), general overlap (full core + own tail), and disjoint-heavy (half
/// the core + a tail a third the size of the core). All tails are disjoint slices of one
/// id pool, so the exact intersection is a core prefix, computable by construction.
fn mixed_sets(n: usize, core: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let tail = core / 10;
    let heavy_tail = core / 3;
    let ids = synth::distinct_ids(core + n * heavy_tail, &mut rng);
    let core_ids = &ids[..core];
    let mut sets = Vec::with_capacity(n);
    let mut coordinator = core_ids.to_vec();
    coordinator.extend_from_slice(&ids[core..core + tail]);
    sets.push(coordinator);
    for i in 1..n {
        let start = core + i * heavy_tail;
        sets.push(match i % 3 {
            0 => core_ids[..core - 5 * i].to_vec(),
            1 => {
                let mut s = core_ids.to_vec();
                s.extend_from_slice(&ids[start..start + tail]);
                s
            }
            _ => {
                let mut s = core_ids[..core / 2].to_vec();
                s.extend_from_slice(&ids[start..start + heavy_tail]);
                s
            }
        });
    }
    sets
}

/// The exact reference: fold `synth::intersect` over the sets.
fn exact_fold(sets: &[Vec<u64>]) -> Vec<u64> {
    let mut acc = sets[0].clone();
    for s in &sets[1..] {
        acc = synth::intersect(&acc, s);
    }
    acc
}

/// The protocol reference: iterate the *two-party* engine over a running intersection —
/// N−1 full `run_pair` sessions. An N-party round must land on exactly this answer (in
/// one round, with one sketch collection, instead of N−1 sequential conversations).
fn run_pair_fold(sets: &[Vec<u64>]) -> Vec<u64> {
    let mut acc = sets[0].clone();
    for (i, s) in sets[1..].iter().enumerate() {
        let alice = Setx::builder(&acc).build().expect("fold alice config");
        let bob = Setx::builder(s).build().expect("fold bob config");
        let (ra, _) = alice.run_pair(&bob).unwrap_or_else(|e| panic!("fold step {i}: {e}"));
        acc = ra.intersection;
    }
    acc.sort_unstable();
    acc
}

/// The headline acceptance: mixed-shape rounds at N = {3, 5, 8}, every party's answer
/// equal to the iterated two-party fold, every per-party transcript summing exactly to
/// the coordinator's total.
#[test]
fn mixed_shape_rounds_match_the_iterated_two_party_fold() {
    for n in [3usize, 5, 8] {
        let sets = mixed_sets(n, 900, 0x1234 + n as u64);
        let expected = exact_fold(&sets);
        assert!(!expected.is_empty(), "degenerate workload at n={n}");
        assert_eq!(run_pair_fold(&sets), expected, "two-party fold reference at n={n}");

        let multi = Setx::builder(&sets[0]).parties(&sets[1..]).expect("multi config");
        let (report, spoke_reports) = multi.run_detailed().expect("multi round");
        assert_eq!(report.intersection, expected, "coordinator at n={n}");
        assert_eq!(report.completed(), n - 1);
        for (p, r) in report.parties.iter().zip(&spoke_reports) {
            assert!(p.error.is_none(), "party {} failed: {:?}", p.party, p.error);
            assert_eq!(r.intersection, expected, "party {} at n={n}", p.party);
            // The coordinator's view of each spoke's transcript equals the spoke's own.
            assert_eq!(
                p.comm.total_bytes(),
                r.total_bytes(),
                "coordinator vs spoke transcript, party {} at n={n}",
                p.party
            );
        }
        let per_party: usize = report.parties.iter().map(|p| p.total_bytes()).sum();
        assert_eq!(per_party, report.total_bytes(), "byte shards must sum at n={n}");
    }
}

/// The failure-isolation acceptance, over real sockets: in a 5-party round, spoke 4
/// joins (completing the roster) and then goes silent. It must be dropped with a typed
/// `PartyTimeout` while the coordinator and the three live spokes finish the round —
/// and commit the intersection of exactly the parties that stayed.
#[test]
fn stalled_party_is_dropped_and_the_rest_complete_over_tcp() {
    let sets = synth::overlap_n(5, 600, 15, 0xBEEF);
    let cfg = *Setx::builder(&sets[0]).build().unwrap().config();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let stall_set = sets[4].clone();
    let staller = std::thread::spawn(move || {
        let mut party = Party::new(&cfg, stall_set, 4, 5).unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        for m in party.start() {
            s.write_all(&m.to_bytes()).unwrap();
        }
        std::thread::sleep(Duration::from_millis(2_500));
        drop(s);
    });
    let live: Vec<_> = (1u32..4)
        .map(|id| {
            let set = sets[id as usize].clone();
            std::thread::spawn(move || join_round(addr, &cfg, set, id, 5))
        })
        .collect();

    let report =
        host_round(&listener, &cfg, sets[0].clone(), 5, Duration::from_millis(700)).unwrap();
    // The committed intersection covers the parties that stayed: coordinator + 1..=3.
    let expected = exact_fold(&sets[..4]);
    assert_eq!(report.intersection, expected);
    assert_eq!(report.completed(), 3);
    let timed_out = report.parties.iter().find(|p| p.party == 4).unwrap();
    assert!(
        matches!(timed_out.error, Some(MultiError::PartyTimeout { party: 4 })),
        "stalled spoke must surface PartyTimeout, got {:?}",
        timed_out.error
    );
    for h in live {
        let r = h.join().expect("spoke thread").expect("live spoke completes");
        assert_eq!(r.intersection, expected);
    }
    staller.join().unwrap();
}
