//! Trace completeness properties for the observability layer ([`commonsense::obs`]).
//!
//! Every session's [`SessionTrace`] must be *well-formed* (non-decreasing timestamps,
//! open/close edges balanced per span kind) and *complete*: exactly one
//! [`SpanKind::Attempt`] span per ladder rung the report counts, and exactly one
//! [`SpanKind::Round`] marker per payload frame (`report.rounds`). Both invariants are
//! structural — the spans are emitted at the same choke points that advance the ladder
//! and charge the comm log — and these tests pin them across workload shapes
//! (subset / overlap / disjoint) × both codec settings, through a forced
//! ladder-escalation run, and through the multi-party coordinator's barrier timeline.
//!
//! [`SessionTrace`]: commonsense::obs::SessionTrace
//! [`SpanKind::Attempt`]: commonsense::obs::SpanKind::Attempt
//! [`SpanKind::Round`]: commonsense::obs::SpanKind::Round

use commonsense::data::synth;
use commonsense::obs::{PhaseDurations, SpanEdge, SpanKind};
use commonsense::setx::{Mode, Setx, SetxReport};
use std::time::Duration;

/// The completeness contract every traced report must satisfy.
fn assert_trace_complete(report: &SetxReport, label: &str) {
    let trace = &report.trace;
    assert!(!trace.is_empty(), "{label}: traced session produced an empty timeline");
    assert!(trace.is_well_formed(), "{label}: unbalanced or out-of-order trace");
    let attempt_spans = trace.count_spans(|k| matches!(k, SpanKind::Attempt(_)));
    assert_eq!(
        attempt_spans,
        report.attempts as usize,
        "{label}: one span per ladder attempt (report says {})",
        report.attempts
    );
    let round_markers = trace.count_spans(|k| k == SpanKind::Round);
    assert_eq!(
        round_markers,
        report.rounds,
        "{label}: one marker per payload frame (report says {})",
        report.rounds
    );
    assert_eq!(
        trace.count_spans(|k| k == SpanKind::Handshake),
        1,
        "{label}: exactly one handshake span"
    );
    // The derived breakdown is consistent: phases are sub-intervals of the whole.
    let pd = report.phase_durations();
    assert!(pd.total >= pd.handshake, "{label}: handshake exceeds total");
    assert!(pd.total >= pd.attempts, "{label}: attempts exceed total");
}

/// Well-formedness and span-count exactness hold across workload shapes × codecs, on
/// both endpoints of the session.
#[test]
fn traces_are_complete_across_shapes_and_codecs() {
    let shapes: [(&str, (Vec<u64>, Vec<u64>)); 3] = [
        ("subset", synth::subset_pair(4_000, 120, 0xA1)),
        ("overlap", synth::overlap_pair(3_000, 80, 120, 0xB2)),
        ("disjoint", synth::overlap_pair(0, 150, 200, 0xC3)),
    ];
    for (shape, (a, b)) in shapes {
        for codec in [true, false] {
            let build = |set: &[u64]| Setx::builder(set).codec(codec).seed(9).build().unwrap();
            let (ra, rb) = build(&a).run_pair(&build(&b)).unwrap();
            assert_eq!(ra.intersection, synth::intersect(&a, &b), "{shape} codec={codec}");
            assert_trace_complete(&ra, &format!("{shape} codec={codec} alice"));
            assert_trace_complete(&rb, &format!("{shape} codec={codec} bob"));
        }
    }
}

/// A deliberately under-provisioned first attempt (safety 0.45) forces the escalation
/// ladder; the trace then carries one span per rung — `Attempt(0)`, `Attempt(1)`, … —
/// each exactly once, and the rung spans carry real (timed) durations.
#[test]
fn forced_escalation_traces_one_span_per_rung() {
    let (a, b) = synth::overlap_pair(6_000, 150, 150, 0x1ad);
    let build = |set: &[u64]| {
        Setx::builder(set).mode(Mode::Bidi).safety(0.45).max_attempts(4).seed(3).build().unwrap()
    };
    let (ra, rb) = build(&a).run_pair(&build(&b)).unwrap();
    assert!(ra.attempts >= 2, "safety 0.45 must fail attempt 0 (attempts = {})", ra.attempts);
    for (label, r) in [("alice", &ra), ("bob", &rb)] {
        assert_trace_complete(r, label);
        for rung in 0..r.attempts {
            assert_eq!(
                r.trace.count_spans(|k| k == SpanKind::Attempt(rung)),
                1,
                "{label}: rung {rung} must appear exactly once"
            );
        }
        let pd = r.phase_durations();
        assert!(pd.total > Duration::ZERO, "{label}: a multi-attempt session takes real time");
    }
}

/// `tracing(false)` is a pure observation ablation: no timeline is recorded, the
/// breakdown degenerates to zero, and neither the answer nor the wire bytes change.
/// Tracing is deliberately outside the config fingerprint, so a mixed pair (one side
/// on, one side off) still negotiates and each side keeps its own setting.
#[test]
fn tracing_off_records_nothing_and_changes_no_answers() {
    let (a, b) = synth::overlap_pair(2_000, 60, 80, 0x5e);
    let build = |set: &[u64], tracing: bool| {
        Setx::builder(set).tracing(tracing).seed(5).build().unwrap()
    };
    let (ra_on, _) = build(&a, true).run_pair(&build(&b, true)).unwrap();
    let (ra_off, rb_off) = build(&a, false).run_pair(&build(&b, false)).unwrap();
    assert!(ra_off.trace.is_empty() && rb_off.trace.is_empty());
    assert_eq!(ra_off.phase_durations(), PhaseDurations::default());
    assert_eq!(ra_on.intersection, ra_off.intersection);
    assert_eq!(ra_on.total_bytes(), ra_off.total_bytes(), "tracing must not touch the wire");
    let (ra_mixed, rb_mixed) = build(&a, true).run_pair(&build(&b, false)).unwrap();
    assert!(!ra_mixed.trace.is_empty(), "traced side still records against an untraced peer");
    assert!(rb_mixed.trace.is_empty(), "untraced side stays silent");
    assert_eq!(ra_mixed.intersection, ra_on.intersection);
}

/// The multi-party coordinator's timeline covers all four barriers, once each, in
/// order, and stays well-formed after absorbing the per-spoke repair sessions.
#[test]
fn multi_party_coordinator_trace_covers_every_barrier() {
    let sets = synth::overlap_n(3, 1_500, 40, 0x77);
    let report = Setx::multi(&sets).unwrap();
    assert_eq!(report.completed(), 2, "both spokes must finish");
    let trace = &report.trace;
    assert!(trace.is_well_formed(), "coordinator trace unbalanced");
    let barriers = [
        SpanKind::MultiJoin,
        SpanKind::MultiCollect,
        SpanKind::MultiConstraint,
        SpanKind::MultiFinal,
    ];
    for kind in barriers {
        assert_eq!(trace.count_spans(|k| k == kind), 1, "{kind:?}: exactly one barrier span");
    }
    // Barriers open in protocol order (join → collect → constraint → final).
    let opens: Vec<SpanKind> = trace
        .events
        .iter()
        .filter(|e| e.edge == SpanEdge::Open && barriers.contains(&e.kind))
        .map(|e| e.kind)
        .collect();
    assert_eq!(opens, barriers, "barrier spans out of order");
}
