//! Property-style randomized tests of the protocol invariants (the image's crate set has
//! no proptest — DESIGN.md §4 — so generators are seeded sweeps with shrink-free repro:
//! every failure message carries the generating seed).

use commonsense::data::synth;
use commonsense::hash::Xoshiro256;
use commonsense::protocol::bidi::{self, BidiOptions};
use commonsense::protocol::{uni, CsParams};

/// Invariant: unidirectional CommonSense is *exact* across random shapes.
#[test]
fn prop_uni_exactness_random_shapes() {
    let mut rng = Xoshiro256::seed_from_u64(0x0901);
    for case in 0..25 {
        let n = 2_000 + rng.gen_range(20_000) as usize;
        let d = 1 + rng.gen_range(400) as usize;
        let seed = rng.next_u64();
        let (a, b) = synth::subset_pair(n, d, seed);
        let params = CsParams::tuned_uni(b.len(), d);
        let out = uni::run(&a, &b, &params).expect("run");
        assert_eq!(
            out.b_minus_a,
            synth::difference(&b, &a),
            "case {case}: n={n} d={d} seed={seed}"
        );
        let mut want = a.clone();
        want.sort_unstable();
        assert_eq!(out.intersection, want, "case {case}");
    }
}

/// Invariant: bidirectional CommonSense converges and is exact on both sides across random
/// shapes, including heavy skew either way.
#[test]
fn prop_bidi_exactness_random_shapes() {
    let mut rng = Xoshiro256::seed_from_u64(0x0902);
    for case in 0..20 {
        let n = 2_000 + rng.gen_range(10_000) as usize;
        let au = rng.gen_range(200) as usize;
        let bu = 1 + rng.gen_range(300) as usize;
        let seed = rng.next_u64();
        let (a, b) = synth::overlap_pair(n, au, bu, seed);
        let params = CsParams::tuned_bidi(n + au + bu, au, bu);
        let out = bidi::run(&a, &b, &params, BidiOptions::default());
        assert!(out.converged, "case {case}: n={n} au={au} bu={bu} seed={seed}");
        assert_eq!(out.a_minus_b, synth::difference(&a, &b), "case {case} seed={seed}");
        assert_eq!(out.b_minus_a, synth::difference(&b, &a), "case {case} seed={seed}");
    }
}

/// Invariant: comm cost is monotone-ish in d and always beats the SetR lower bound at
/// small d/|B| (the paper's headline).
#[test]
fn prop_uni_cost_beats_setr_bound() {
    for (n, d, seed) in [(30_000usize, 100usize, 1u64), (30_000, 500, 2), (50_000, 1_000, 3)] {
        let (a, b) = synth::subset_pair(n, d, seed);
        let params = CsParams::tuned_uni(b.len(), d);
        let out = uni::run(&a, &b, &params).expect("run");
        let setr = commonsense::bounds::setr_lower_bound_bits(64, d as u64) / 8.0;
        assert!(
            (out.comm.total_bytes() as f64) < setr,
            "n={n} d={d}: {} !< {setr}",
            out.comm.total_bytes()
        );
    }
}

/// Invariant: the d-estimate can be off by ±30% and the protocol stays exact (the paper
/// assumes d known via sketch-based estimators, which carry exactly this kind of error).
/// Failures are *typed* now: an undersized run must surface as a `Decode` error carrying
/// which layer failed, never a bare `None` or a wrong answer.
#[test]
fn prop_robust_to_d_estimate_error() {
    use commonsense::protocol::uni::UniError;
    for (mult, seed) in [(0.7f64, 11u64), (1.3, 12), (2.0, 13)] {
        let d = 300usize;
        let (a, b) = synth::subset_pair(20_000, d, seed);
        let d_est = ((d as f64) * mult) as usize;
        let mut params = CsParams::tuned_uni(b.len(), d_est);
        params.est_b_unique = d_est;
        // Underestimates shrink l; the decoder may need the fallback, but must stay exact
        // whenever it reports success.
        match uni::run(&a, &b, &params) {
            Ok(out) => {
                if mult >= 1.0 || out.b_minus_a.len() == d {
                    assert_eq!(out.b_minus_a, synth::difference(&b, &a), "mult={mult}");
                }
            }
            Err(e) => {
                assert!(mult < 1.0, "only underestimates may fail (got {e})");
                assert!(
                    matches!(e, UniError::Decode(_)),
                    "failure must be a typed decode error, got {e}"
                );
            }
        }
    }
}

/// Invariant: with the SMF disabled, bidirectional decoding must suffer (common
/// hallucinations / non-convergence) strictly more often than with it — the §5.2 ablation.
#[test]
fn prop_smf_prevents_common_hallucinations() {
    let mut with_smf_ok = 0;
    let mut without_smf_ok = 0;
    for seed in 0..12u64 {
        let (a, b) = synth::overlap_pair(4_000, 80, 80, 0xab1a + seed);
        // Marginal l to provoke hallucinations.
        let mut params = CsParams::tuned_bidi(4_160, 80, 80);
        params.l = (params.l as f64 / 1.45) as u32;
        let opts_on = BidiOptions::default();
        let mut opts_off = BidiOptions::default();
        opts_off.smf_fpr = 1.0; // filter saturates ⇒ bans nothing ⇒ no avoidance
        // smf_fpr = 1.0 makes every test positive... which bans everything. Instead,
        // disable by making the filter miss everything: fpr → tiny means large filter;
        // emulate "off" by confident_round = 0 and fpr ≈ 1 is ambiguous — use a
        // dedicated flag-free trick: fpr very close to 1 bans ~everything, which models
        // "no collision avoidance" *plus* no automatic sets; too harsh. So instead we
        // compare default vs no-resolution (confident_round beyond the cap ⇒ inquiries
        // never fire and SMF false positives are never resolved).
        let mut opts_no_resolution = opts_on;
        opts_no_resolution.confident_round = 10_000;
        let out_on = bidi::run(&a, &b, &params, opts_on);
        let out_off = bidi::run(&a, &b, &params, opts_no_resolution);
        let exact_on = out_on.converged
            && out_on.a_minus_b == synth::difference(&a, &b)
            && out_on.b_minus_a == synth::difference(&b, &a);
        let exact_off = out_off.converged
            && out_off.a_minus_b == synth::difference(&a, &b)
            && out_off.b_minus_a == synth::difference(&b, &a);
        with_smf_ok += exact_on as u32;
        without_smf_ok += exact_off as u32;
    }
    assert!(
        with_smf_ok >= without_smf_ok,
        "resolution must not hurt: {with_smf_ok} vs {without_smf_ok}"
    );
    assert!(with_smf_ok >= 10, "full protocol too weak at marginal l: {with_smf_ok}/12");
}

/// Invariant: the sans-io session engine enforces frame order — out-of-phase frames are
/// errors (never panics, never silent acceptance), and an errored session stays closed.
#[test]
fn prop_session_frame_order_is_enforced() {
    use commonsense::entropy::SketchMsg;
    use commonsense::protocol::session::{Session, SessionError, SessionEvent};
    use commonsense::protocol::wire::Msg;

    let set: Vec<u64> = (0..100).collect();
    let round = Msg::Round {
        residue: vec![],
        smf: None,
        inquiry: vec![],
        answers: vec![],
        done: false,
        codec: false,
    };
    let sketch = Msg::Sketch {
        sketch: SketchMsg { n: 4, table: vec![], payload: vec![], syndromes: vec![] },
        codec: false,
    };
    let hello = Msg::Hello {
        l: 256,
        m: 5,
        seed: 9,
        universe_bits: 64,
        est_initiator_unique: 4,
        est_responder_unique: 4,
        set_len: 100,
        namespace: 0,
    };

    // Round or Sketch before Hello: rejected.
    for premature in [&round, &sketch] {
        let mut s = Session::responder(&set, BidiOptions::default(), false);
        assert!(matches!(s.on_msg(premature), Err(SessionError::UnexpectedMessage { .. })));
    }
    // Hello is accepted exactly once; a second Hello is out of phase.
    let mut s = Session::responder(&set, BidiOptions::default(), false);
    assert!(matches!(s.on_msg(&hello), Ok(SessionEvent::Continue)));
    assert!(matches!(s.on_msg(&hello), Err(SessionError::UnexpectedMessage { .. })));
    // And the failed session is closed for good.
    assert!(s.on_msg(&round).is_err());
    assert!(!s.is_settled());
    assert!(s.outcome().unique.is_empty());
}

/// Invariant: protocol outcome is a pure function of (sets, params, options).
#[test]
fn prop_deterministic_replay() {
    let (a, b) = synth::overlap_pair(6_000, 50, 90, 999);
    let params = CsParams::tuned_bidi(6_140, 50, 90);
    let o1 = bidi::run(&a, &b, &params, BidiOptions::default());
    let o2 = bidi::run(&a, &b, &params, BidiOptions::default());
    assert_eq!(o1.a_minus_b, o2.a_minus_b);
    assert_eq!(o1.b_minus_a, o2.b_minus_a);
    assert_eq!(o1.comm.total_bytes(), o2.comm.total_bytes());
    assert_eq!(o1.rounds, o2.rounds);
}

/// Invariant: disjoint sets (empty intersection) and identical sets both terminate.
#[test]
fn prop_degenerate_overlaps() {
    // Identical sets.
    let (a, _) = synth::subset_pair(3_000, 0, 5);
    let params = CsParams::tuned_bidi(3_000, 1, 1);
    let out = bidi::run(&a, &a, &params, BidiOptions::default());
    assert!(out.converged);
    assert!(out.a_minus_b.is_empty() && out.b_minus_a.is_empty());
    assert_eq!(out.intersection.len(), 3_000);

    // Tiny sets, fully disjoint.
    let (x, y) = synth::overlap_pair(0, 40, 60, 6);
    let params = CsParams::tuned_bidi(100, 40, 60);
    let out = bidi::run(&x, &y, &params, BidiOptions::default());
    assert!(out.converged);
    assert_eq!(out.a_minus_b.len(), 40);
    assert_eq!(out.b_minus_a.len(), 60);
    assert!(out.intersection.is_empty());
}
