//! Property-style randomized tests of the codec/substrate invariants.

use commonsense::ecc::{BchSyndrome, GF2m};
use commonsense::entropy::{
    compress_residue, compress_sketch, decompress_residue, recover_sketch, SketchCodecParams,
};
use commonsense::hash::Xoshiro256;
use commonsense::matrix::CsMatrix;
use commonsense::protocol::wire::Msg;
use commonsense::sketch::Sketch;
use std::sync::Arc;

/// rANS residue codec roundtrips arbitrary small-integer vectors, including adversarially
/// spiky ones.
#[test]
fn prop_residue_codec_roundtrip() {
    let mut rng = Xoshiro256::seed_from_u64(0xc0dec);
    for case in 0..40 {
        let n = rng.gen_range(3_000) as usize;
        let spread = 1 + rng.gen_range(30) as i64;
        let values: Vec<i32> = (0..n)
            .map(|_| {
                let v = (rng.gen_range(2 * spread as u64 + 1) as i64 - spread) as i32;
                if rng.gen_f64() < 0.002 {
                    v.saturating_mul(100_001) // rare outlier → escape path
                } else {
                    v
                }
            })
            .collect();
        let bytes = compress_residue(&values);
        let back = decompress_residue(&bytes, n).expect("decode");
        assert_eq!(back, values, "case {case} n={n} spread={spread}");
    }
}

/// Statistical truncation + parity patch: exact recovery across random set geometries.
#[test]
fn prop_truncation_roundtrip_random_geometries() {
    let mut rng = Xoshiro256::seed_from_u64(0x7204);
    for case in 0..12 {
        let l = 512 + 128 * rng.gen_range(12) as u32;
        let m = 5 + 2 * rng.gen_range(2) as u32;
        let n_common = 2_000 + rng.gen_range(10_000) as usize;
        let a_only = rng.gen_range(80) as usize;
        let b_only = rng.gen_range(200) as usize;
        let mat = CsMatrix::new(l, m, rng.next_u64());
        let common: Vec<u64> = (0..n_common as u64).map(|i| i * 3 + 1).collect();
        let mut a: Vec<u64> = common.clone();
        a.extend((0..a_only as u64).map(|i| 1_000_000_000 + i));
        let mut b = common;
        b.extend((0..b_only as u64).map(|i| 2_000_000_000 + i));
        let ska = Sketch::encode(mat, &a);
        let skb = Sketch::encode(mat, &b);
        let params = SketchCodecParams::derive(b_only, a_only, l, m);
        let msg = compress_sketch(&ska.counts, &params);
        let (x_hat, _, unresolved) = recover_sketch(&msg, &skb.counts, &params).expect("recover");
        assert_eq!(unresolved, 0, "case {case}");
        assert_eq!(x_hat, ska.counts, "case {case}: l={l} m={m}");
    }
}

/// BCH syndrome decoding: exact for weights ≤ t, detected for weights in (t, 3t].
#[test]
fn prop_bch_capacity_boundary() {
    let gf = Arc::new(GF2m::new(13));
    let mut rng = Xoshiro256::seed_from_u64(0xbc4);
    for case in 0..30 {
        let t = 2 + rng.gen_range(30) as usize;
        let w = 1 + rng.gen_range(3 * t as u64) as usize;
        let mut positions: Vec<u32> = Vec::new();
        while positions.len() < w {
            let p = rng.gen_range(8000) as u32;
            if !positions.contains(&p) {
                positions.push(p);
            }
        }
        let s = BchSyndrome::compute(gf.clone(), t, positions.iter().copied());
        match s.decode(8191) {
            Ok(mut got) => {
                if w <= t {
                    // Within capacity: decoding must be exact.
                    got.sort_unstable();
                    positions.sort_unstable();
                    assert_eq!(got, positions, "case {case}");
                } else {
                    // Beyond capacity BCH may *miscorrect* (return a different small-weight
                    // vector with the same syndromes) — a classic property, tolerated by
                    // both consumers (the truncation codec treats it as decoder noise and
                    // PinSketch is provisioned with t ≥ d). It must at least be small.
                    assert!(got.len() <= t, "case {case}: miscorrection weight {} > t", got.len());
                }
            }
            Err(_) => {
                assert!(w > t, "case {case}: failed within capacity (w={w}, t={t})");
            }
        }
    }
}

/// Wire parser never panics on truncated/corrupted frames (fuzz-style).
#[test]
fn prop_wire_fuzz_no_panic() {
    let mut rng = Xoshiro256::seed_from_u64(0xf022);
    // Seed corpus: real frames, then mutate.
    let real = Msg::Round {
        residue: compress_residue(&[1, -2, 0, 3]),
        smf: Some(vec![9; 33]),
        inquiry: vec![42, 43],
        answers: vec![true, false, true],
        done: false,
        codec: false,
    }
    .to_bytes();
    // Codec-on sibling frame (columnar round type byte) — fuzz both corpora.
    let real_c = Msg::Round {
        residue: compress_residue(&[1, -2, 0, 3]),
        smf: Some(vec![9; 33]),
        inquiry: vec![42, 43],
        answers: vec![true, false, true],
        done: false,
        codec: true,
    }
    .to_bytes();
    for corpus in [&real, &real_c] {
        for _ in 0..2_000 {
            let mut frame = corpus.clone();
            let cut = rng.gen_range(frame.len() as u64 + 1) as usize;
            frame.truncate(cut);
            for _ in 0..rng.gen_range(8) {
                if frame.is_empty() {
                    break;
                }
                let pos = rng.gen_range(frame.len() as u64) as usize;
                frame[pos] ^= rng.next_u64() as u8;
            }
            let _ = Msg::from_bytes(&frame); // must not panic
        }
    }
    // Pure garbage too.
    for _ in 0..2_000 {
        let n = rng.gen_range(64) as usize;
        let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let _ = Msg::from_bytes(&junk);
    }
}

/// Sketch linearity (the property every protocol step leans on):
/// sk(A) + sk(B) = sk(A ⊎ B) and sk(B) − sk(A) depends only on the symmetric difference.
#[test]
fn prop_sketch_linearity() {
    let mut rng = Xoshiro256::seed_from_u64(0x11ea);
    for _ in 0..20 {
        let mat = CsMatrix::new(256 + 64 * rng.gen_range(8) as u32, 5, rng.next_u64());
        let common: Vec<u64> = (0..rng.gen_range(2_000)).map(|_| rng.next_u64()).collect();
        let ua: Vec<u64> = (0..rng.gen_range(50)).map(|_| rng.next_u64()).collect();
        let ub: Vec<u64> = (0..rng.gen_range(50)).map(|_| rng.next_u64()).collect();
        let mut a = common.clone();
        a.extend(&ua);
        let mut b = common.clone();
        b.extend(&ub);
        let diff_full = Sketch::encode(mat, &b).sub(&Sketch::encode(mat, &a));
        let diff_uniques = Sketch::encode(mat, &ub).sub(&Sketch::encode(mat, &ua));
        assert_eq!(diff_full, diff_uniques);
    }
}
