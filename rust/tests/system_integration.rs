//! Cross-layer integration: TCP sessions on realistic workloads, the PJRT runtime against
//! the rust sparse path, streaming apps over the full pipeline, partitioned scale-out.

use commonsense::coordinator::{connect_initiator, parallel, serve_responder};
use commonsense::data::ethereum::{diff_stats, EthSim};
use commonsense::data::synth;
use commonsense::matrix::CsMatrix;
use commonsense::protocol::bidi::BidiOptions;
use commonsense::protocol::CsParams;
use commonsense::runtime::Runtime;
use commonsense::sketch::Sketch;
use std::net::TcpListener;

#[test]
fn tcp_ethereum_session_end_to_end() {
    let mut sim = EthSim::genesis(30_000, 0x517e);
    let b = sim.snapshot_ids();
    sim.advance_days(3);
    let a = sim.snapshot_ids();
    let st = diff_stats(&b, &a);

    let params = CsParams::tuned_bidi(a.len().max(b.len()), st.s_minus_a, st.a_minus_s);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let a2 = a.clone();
    let alice = std::thread::spawn(move || {
        serve_responder(&listener, &a2, BidiOptions::default()).unwrap()
    });
    let bob = connect_initiator(addr, &b, &params, BidiOptions::default()).unwrap();
    let alice = alice.join().unwrap();

    assert!(bob.converged && alice.converged);
    assert_eq!(bob.unique, synth::difference(&b, &a));
    assert_eq!(alice.unique, synth::difference(&a, &b));
    // The headline at integration scale: on-wire bytes ≪ shipping either snapshot.
    let wire = bob.bytes_sent + alice.bytes_sent;
    assert!(wire < 8 * b.len() / 4, "wire bytes {wire}");
}

#[test]
fn runtime_agrees_with_sparse_and_decodes() {
    let Ok(rt) = Runtime::load_default() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let shapes = rt.shapes;
    let matrix = CsMatrix::new(shapes.l as u32, 5, 0x90);
    // Encode agreement on a multi-chunk set.
    let ids: Vec<u64> = (0..(2 * shapes.nb + 37) as u64).map(|i| i * 13 + 5).collect();
    let accel = rt.encode_set(matrix, &ids).unwrap();
    assert_eq!(accel, Sketch::encode(matrix, &ids).counts);

    // Correlate agreement with a hand-computed dot.
    let block_ids: Vec<u64> = ids.iter().copied().take(shapes.nb).collect();
    let block = matrix.dense_block_rowmajor(&block_ids, shapes.nb);
    let sk = Sketch::encode(matrix, &block_ids[..40]);
    let r: Vec<f32> = sk.counts.iter().map(|&c| c as f32).collect();
    let delta = rt.correlate_block(&block, &r, 5.0).unwrap();
    for (j, &id) in block_ids.iter().enumerate().take(60) {
        let mut dot = 0i32;
        for row in matrix.column(id) {
            dot += sk.counts[row as usize];
        }
        let want = dot as f32 / 5.0;
        assert!((delta[j] - want).abs() < 1e-4, "j={j}: {} vs {want}", delta[j]);
    }
}

#[test]
fn partitioned_parallel_on_ethereum_workload() {
    let mut sim = EthSim::genesis(40_000, 0x9a2);
    let b = sim.snapshot_ids();
    sim.advance_days(2);
    let a = sim.snapshot_ids();
    let st = diff_stats(&b, &a);
    let out = parallel::setx(
        &a,
        &b,
        st.a_minus_s,
        st.s_minus_a,
        4,
        4,
        BidiOptions::default(),
    );
    assert!(out.converged);
    assert_eq!(out.a_minus_b, synth::difference(&a, &b));
    assert_eq!(out.b_minus_a, synth::difference(&b, &a));
}

#[test]
fn streaming_digest_composes_with_protocol_params() {
    use commonsense::streaming::{digest_params, StreamDigest};
    // Digest built from protocol-tuned params decodes a realistic churn stream.
    let catalog: Vec<u64> = (0..20_000u64).map(|i| i * 7 + 3).collect();
    let params = digest_params(catalog.len(), 100);
    let mut digest = StreamDigest::new(params.matrix());
    for &id in catalog.iter().take(5_000) {
        digest.add(id);
    }
    for &id in catalog.iter().take(5_000).skip(80) {
        digest.remove(id);
    }
    let got = digest.decode(&catalog).expect("decode");
    assert_eq!(got, catalog[..80].to_vec());
}

#[test]
fn tcp_and_in_memory_frontends_account_identical_bytes() {
    // One sans-io Session engine behind every transport ⇒ the transport cannot change
    // the conversation: a TCP run and an in-memory run of the same workload must
    // exchange byte-identical traffic and reach identical results.
    let (a, b) = synth::overlap_pair(3_000, 40, 60, 21);
    let params = CsParams::tuned_bidi(3_100, 40, 60);
    let mem = commonsense::protocol::bidi::run(&a, &b, &params, BidiOptions::default());
    assert!(mem.converged);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let b2 = b.clone();
    let bob = std::thread::spawn(move || {
        serve_responder(&listener, &b2, BidiOptions::default()).unwrap()
    });
    let alice = connect_initiator(addr, &a, &params, BidiOptions::default()).unwrap();
    let bob = bob.join().unwrap();
    assert!(alice.converged && bob.converged);
    assert_eq!(alice.unique, mem.a_minus_b);
    assert_eq!(bob.unique, mem.b_minus_a);
    assert_eq!(alice.bytes_sent + bob.bytes_sent, mem.comm.total_bytes());
}

#[test]
fn parallel_pool_is_bounded_at_integration_scale() {
    // The §7.3 scale-out on a big partition fan-out: exactness plus the thread cap.
    let (a, b) = synth::overlap_pair(20_000, 160, 160, 0x77);
    let out = parallel::setx(&a, &b, 160, 160, 64, 4, BidiOptions::default());
    assert!(out.converged);
    assert_eq!(out.a_minus_b, synth::difference(&a, &b));
    assert_eq!(out.b_minus_a, synth::difference(&b, &a));
    assert_eq!(out.partitions, 64);
    assert!(out.peak_workers <= 4, "thread cap violated: {}", out.peak_workers);
}

#[test]
fn concurrent_tcp_sessions_are_independent() {
    // Two sessions on different ports, different workloads, run concurrently.
    let mk = |seed: u64| synth::overlap_pair(3_000, 30, 60, seed);
    let mut joins = Vec::new();
    for seed in [1u64, 2] {
        joins.push(std::thread::spawn(move || {
            let (a, b) = mk(seed);
            let params = CsParams::tuned_bidi(3_090, 30, 60);
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let b2 = b.clone();
            let srv = std::thread::spawn(move || {
                serve_responder(&listener, &b2, BidiOptions::default()).unwrap()
            });
            let cli = connect_initiator(addr, &a, &params, BidiOptions::default()).unwrap();
            let srv = srv.join().unwrap();
            assert_eq!(cli.unique, synth::difference(&a, &b), "seed {seed}");
            assert_eq!(srv.unique, synth::difference(&b, &a), "seed {seed}");
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}
