//! Cross-layer integration: TCP sessions on realistic workloads, the PJRT runtime against
//! the rust sparse path, streaming apps over the full pipeline, partitioned scale-out.

use commonsense::coordinator::{connect, parallel, serve};
use commonsense::data::ethereum::{diff_stats, EthSim};
use commonsense::data::synth;
use commonsense::matrix::CsMatrix;
use commonsense::metrics::Phase;
use commonsense::protocol::bidi::BidiOptions;
use commonsense::runtime::Runtime;
use commonsense::setx::Setx;
use commonsense::sketch::Sketch;
use std::net::TcpListener;

#[test]
fn tcp_ethereum_session_end_to_end() {
    let mut sim = EthSim::genesis(30_000, 0x517e);
    let b = sim.snapshot_ids();
    sim.advance_days(3);
    let a = sim.snapshot_ids();
    let st = diff_stats(&b, &a);

    // No ground truth supplied: the builder defaults estimate d in the handshake.
    let alice = Setx::builder(&a).universe_bits(256).build().unwrap();
    let bob = Setx::builder(&b).universe_bits(256).build().unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let alice2 = alice.clone();
    let server = std::thread::spawn(move || serve(&listener, &alice2).unwrap());
    let bob_report = connect(addr, &bob).unwrap();
    let alice_report = server.join().unwrap();

    assert!(bob_report.converged && alice_report.converged);
    assert_eq!(bob_report.local_unique, synth::difference(&b, &a));
    assert_eq!(alice_report.local_unique, synth::difference(&a, &b));
    assert_eq!(bob_report.local_unique.len(), st.s_minus_a);
    // The headline at integration scale: on-wire bytes ≪ shipping either snapshot.
    let wire = bob_report.total_bytes();
    assert!(wire < 8 * b.len() / 4, "wire bytes {wire}");
}

#[test]
fn runtime_agrees_with_sparse_and_decodes() {
    let Ok(rt) = Runtime::load_default() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let shapes = rt.shapes;
    let matrix = CsMatrix::new(shapes.l as u32, 5, 0x90);
    // Encode agreement on a multi-chunk set.
    let ids: Vec<u64> = (0..(2 * shapes.nb + 37) as u64).map(|i| i * 13 + 5).collect();
    let accel = rt.encode_set(matrix, &ids).unwrap();
    assert_eq!(accel, Sketch::encode(matrix, &ids).counts);

    // Correlate agreement with a hand-computed dot.
    let block_ids: Vec<u64> = ids.iter().copied().take(shapes.nb).collect();
    let block = matrix.dense_block_rowmajor(&block_ids, shapes.nb);
    let sk = Sketch::encode(matrix, &block_ids[..40]);
    let r: Vec<f32> = sk.counts.iter().map(|&c| c as f32).collect();
    let delta = rt.correlate_block(&block, &r, 5.0).unwrap();
    for (j, &id) in block_ids.iter().enumerate().take(60) {
        let mut dot = 0i32;
        for row in matrix.column(id) {
            dot += sk.counts[row as usize];
        }
        let want = dot as f32 / 5.0;
        assert!((delta[j] - want).abs() < 1e-4, "j={j}: {} vs {want}", delta[j]);
    }
}

#[test]
fn partitioned_parallel_on_ethereum_workload() {
    let mut sim = EthSim::genesis(40_000, 0x9a2);
    let b = sim.snapshot_ids();
    sim.advance_days(2);
    let a = sim.snapshot_ids();
    let st = diff_stats(&b, &a);
    let out = parallel::setx(
        &a,
        &b,
        st.a_minus_s,
        st.s_minus_a,
        4,
        4,
        BidiOptions::default(),
    );
    assert!(out.converged);
    assert_eq!(out.a_minus_b, synth::difference(&a, &b));
    assert_eq!(out.b_minus_a, synth::difference(&b, &a));
}

#[test]
fn streaming_digest_composes_with_protocol_params() {
    use commonsense::streaming::{digest_params, StreamDigest};
    // Digest built from protocol-tuned params decodes a realistic churn stream.
    let catalog: Vec<u64> = (0..20_000u64).map(|i| i * 7 + 3).collect();
    let params = digest_params(catalog.len(), 100);
    let mut digest = StreamDigest::new(params.matrix());
    for &id in catalog.iter().take(5_000) {
        digest.add(id);
    }
    for &id in catalog.iter().take(5_000).skip(80) {
        digest.remove(id);
    }
    let got = digest.decode(&catalog).expect("decode");
    assert_eq!(got, catalog[..80].to_vec());
}

#[test]
fn tcp_and_in_memory_frontends_account_identical_bytes() {
    // One endpoint engine behind every transport ⇒ the transport cannot change the
    // conversation: a TCP run and an in-memory run of the same workload must exchange
    // byte-identical traffic — phase by phase, direction by direction — and reach
    // identical results.
    let (a, b) = synth::overlap_pair(3_000, 40, 60, 21);
    let alice = Setx::builder(&a).build().unwrap();
    let bob = Setx::builder(&b).build().unwrap();
    let (mem_a, mem_b) = alice.run_pair(&bob).unwrap();
    assert!(mem_a.converged && mem_b.converged);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let bob2 = bob.clone();
    let server = std::thread::spawn(move || serve(&listener, &bob2).unwrap());
    let tcp_a = connect(addr, &alice).unwrap();
    let tcp_b = server.join().unwrap();
    assert!(tcp_a.converged && tcp_b.converged);
    assert_eq!(tcp_a.local_unique, mem_a.local_unique);
    assert_eq!(tcp_b.local_unique, mem_b.local_unique);
    assert_eq!(tcp_a.intersection, mem_a.intersection);
    for phase in Phase::ALL {
        assert_eq!(tcp_a.phase_sent(phase), mem_a.phase_sent(phase), "{}", phase.name());
        assert_eq!(
            tcp_a.phase_received(phase),
            mem_a.phase_received(phase),
            "{}",
            phase.name()
        );
    }
    assert_eq!(tcp_a.total_bytes(), mem_a.total_bytes());
    assert_eq!(tcp_b.total_bytes(), mem_b.total_bytes());
}

#[test]
fn parallel_pool_is_bounded_at_integration_scale() {
    // The §7.3 scale-out on a big partition fan-out: exactness plus the thread cap.
    let (a, b) = synth::overlap_pair(20_000, 160, 160, 0x77);
    let out = parallel::setx(&a, &b, 160, 160, 64, 4, BidiOptions::default());
    assert!(out.converged);
    assert_eq!(out.a_minus_b, synth::difference(&a, &b));
    assert_eq!(out.b_minus_a, synth::difference(&b, &a));
    assert_eq!(out.partitions, 64);
    assert!(out.peak_workers <= 4, "thread cap violated: {}", out.peak_workers);
}

#[test]
fn concurrent_tcp_sessions_are_independent() {
    // Two sessions on different ports, different workloads, run concurrently.
    let mk = |seed: u64| synth::overlap_pair(3_000, 30, 60, seed);
    let mut joins = Vec::new();
    for seed in [1u64, 2] {
        joins.push(std::thread::spawn(move || {
            let (a, b) = mk(seed);
            let alice = Setx::builder(&a).build().unwrap();
            let bob = Setx::builder(&b).build().unwrap();
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let bob2 = bob.clone();
            let srv = std::thread::spawn(move || serve(&listener, &bob2).unwrap());
            let cli = connect(addr, &alice).unwrap();
            let srv = srv.join().unwrap();
            assert_eq!(cli.local_unique, synth::difference(&a, &b), "seed {seed}");
            assert_eq!(srv.local_unique, synth::difference(&b, &a), "seed {seed}");
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}
