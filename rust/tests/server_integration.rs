//! System-level tests for the multi-tenant reconciliation daemon (`commonsense::server`):
//! fleets of concurrent TCP clients against one `SetxServer`, checked element-for-element
//! against the in-memory reference, plus the admission-control, timeout, pool-efficiency,
//! tenancy, and graceful-shutdown contracts — including a ≥1k-client mixed-tenant fleet
//! on four poller threads.
//!
//! Every listener binds `127.0.0.1:0` (an OS-assigned ephemeral port), so these tests
//! are safe under any `--test-threads` level — nothing races on a fixed port.

use commonsense::data::synth;
use commonsense::server::loadgen::{self, LoadgenConfig};
use commonsense::server::SetxServer;
use commonsense::setx::multi::net::join_round;
use commonsense::setx::multi::{MultiError, Party};
use commonsense::setx::transport::TcpTransport;
use commonsense::setx::{Setx, SetxError};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

// setrlimit(2), hand-rolled: the 1k-client test needs ~3 fds per session (client
// socket, server socket, slack) and the default soft cap is often exactly 1024.
const RLIMIT_NOFILE: i32 = 7;

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

extern "C" {
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
}

/// Raise the fd soft limit toward `want` (bounded by the hard limit); returns the
/// effective soft limit so callers can scale down instead of failing.
fn raise_nofile(want: u64) -> u64 {
    unsafe {
        let mut lim = RLimit { rlim_cur: 0, rlim_max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
            return 1024;
        }
        if lim.rlim_cur < want {
            let raised =
                RLimit { rlim_cur: want.min(lim.rlim_max), rlim_max: lim.rlim_max };
            if setrlimit(RLIMIT_NOFILE, &raised) == 0 {
                return raised.rlim_cur;
            }
        }
        lim.rlim_cur
    }
}

/// Poll `cond` until it holds or the deadline passes (worker counters update
/// asynchronously after a client sees its last frame).
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The issue's headline workload: 32 concurrent clients of three shapes — subset
/// (client ⊆ host), general overlap, and disjoint-heavy (a third of each set unique) —
/// against a 4-worker server, every report equal to the `run_pair` in-memory reference.
#[test]
fn thirty_two_mixed_clients_match_the_in_memory_reference() {
    let host: Vec<u64> = (0..3_000).collect();
    let server = SetxServer::builder(Setx::builder(&host).build().unwrap())
        .workers(4)
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    let client_sets: Vec<Vec<u64>> = (0..32u64)
        .map(|i| match i % 3 {
            // Subset: Mode::Auto routes these through the unidirectional protocol.
            0 => host[..(3_000 - 40 - 3 * i as usize)].to_vec(),
            // General overlap: a few hundred unique on each side.
            1 => {
                let mut set = host[..2_500].to_vec();
                set.extend(100_000 + i * 1_000..100_000 + i * 1_000 + 230);
                set
            }
            // Disjoint-heavy: a third of either set is unique to it.
            _ => {
                let mut set = host[..2_000].to_vec();
                set.extend(200_000 + i * 10_000..200_000 + i * 10_000 + 1_000);
                set
            }
        })
        .collect();

    let bob = Setx::builder(&host).build().unwrap();
    let outcomes: Vec<(usize, Result<(), String>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = client_sets
            .iter()
            .enumerate()
            .map(|(i, set)| {
                let bob = &bob;
                scope.spawn(move || {
                    let alice = Setx::builder(set).build().expect("client config");
                    // In-memory reference first (its own Setx clone so decoder caches
                    // don't couple the two runs).
                    let (ref_client, _ref_server) =
                        alice.clone().run_pair(&bob.clone()).expect("reference run");
                    let run = || -> Result<(), String> {
                        let mut transport =
                            TcpTransport::connect(addr).map_err(|e| e.to_string())?;
                        let report = alice.run(&mut transport).map_err(|e| e.to_string())?;
                        if report.intersection != ref_client.intersection {
                            return Err(format!(
                                "intersection mismatch: {} vs reference {}",
                                report.intersection.len(),
                                ref_client.intersection.len()
                            ));
                        }
                        if report.local_unique != ref_client.local_unique {
                            return Err("local_unique mismatch".to_string());
                        }
                        Ok(())
                    };
                    (i, run())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    for (i, outcome) in &outcomes {
        assert!(outcome.is_ok(), "client {i} (shape {}): {outcome:?}", i % 3);
    }
    wait_until("all 32 sessions to be counted", || server.stats().sessions_served >= 32);
    let stats = server.shutdown();
    assert_eq!(stats.sessions_served, 32, "stats: {stats:?}");
    assert_eq!(stats.sessions_failed, 0, "last failure: {:?}", stats);
    assert_eq!(stats.sessions_rejected, 0);
    assert!(stats.peak_workers <= 4, "bounded pool violated: {}", stats.peak_workers);
    // Which poller wins each accept race is scheduling-dependent, so only the bound is
    // asserted; the burst itself must overlap connections.
    assert!(stats.peak_workers >= 1);
    assert!(stats.peak_inflight >= 2, "a 32-client burst must overlap connections");
    assert!(stats.total_bytes() > 0);
}

/// Over-admission: at `max_inflight_sessions` live sessions a new connection gets the
/// typed `Busy` frame, surfaced by the client facade as `SetxError::ServerBusy` — not a
/// hang, not a reset.
#[test]
fn over_admission_surfaces_server_busy() {
    let host: Vec<u64> = (0..1_000).collect();
    let server = SetxServer::builder(Setx::builder(&host).build().unwrap())
        .workers(1)
        .max_inflight_sessions(1)
        .timeouts(Some(Duration::from_secs(5)), Some(Duration::from_secs(5)))
        .busy_retry_hint_ms(70)
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    // Occupy the one admission slot with a connection that never speaks (it holds a
    // slot from accept on, even though it never routes to a tenant).
    let stalled = TcpStream::connect(addr).unwrap();
    wait_until("the stalled connection to be admitted", || server.stats().inflight == 1);

    // The next client must be turned away with the typed error (and the hint). An
    // admission-cap rejection happens before routing, so the Busy frame carries
    // tenant 0.
    let client: Vec<u64> = (0..900).collect();
    let alice = Setx::builder(&client).build().unwrap();
    let mut transport = TcpTransport::connect(addr).unwrap();
    match alice.run(&mut transport) {
        Err(SetxError::ServerBusy { retry_after_ms, namespace }) => {
            assert_eq!(retry_after_ms, 70);
            assert_eq!(namespace, 0);
        }
        other => panic!("over-admission must be ServerBusy, got {other:?}"),
    }

    // Release the slot; the same client is now admitted and served.
    drop(stalled);
    wait_until("the stalled session to be reaped", || server.stats().inflight == 0);
    let report = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
    assert_eq!(report.intersection, client);

    wait_until("final session counts", || {
        let s = server.stats();
        s.sessions_served == 1 && s.sessions_failed == 1
    });
    let stats = server.shutdown();
    assert_eq!(stats.sessions_rejected, 1);
    assert_eq!(stats.unrouted_rejected, 1, "the cap rejection never reached a tenant");
    // `accepted` counts *routed* sessions: the stalled connection held a slot but died
    // before its EstHello, so only the served client is accepted…
    assert_eq!(stats.sessions_accepted, 1);
    // …and its failure lands in the unrouted remainder, not a tenant shard.
    assert_eq!(stats.unrouted_failed, 1);
}

/// Satellite regression: a client that stalls mid-handshake is timed out by the
/// per-connection read timeout, freeing the worker — it must not wedge forever.
#[test]
fn slow_client_times_out_and_frees_the_worker() {
    let host: Vec<u64> = (0..1_200).collect();
    let server = SetxServer::builder(Setx::builder(&host).build().unwrap())
        .workers(1)
        .timeouts(Some(Duration::from_millis(150)), Some(Duration::from_millis(150)))
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    let slow = TcpStream::connect(addr).unwrap(); // connects, then sends nothing
    wait_until("the slow client to be timed out", || server.stats().sessions_failed == 1);
    // The single worker is free again: a real client completes normally.
    let client: Vec<u64> = (0..1_000).collect();
    let alice = Setx::builder(&client).build().unwrap();
    let report = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
    assert_eq!(report.intersection, client);
    drop(slow);
    wait_until("the served session to be counted", || server.stats().sessions_served == 1);
    let stats = server.shutdown();
    assert_eq!(stats.sessions_failed, 1);
    assert_eq!(stats.sessions_served, 1);
}

/// Satellite regression: a half-open client — a partial frame *header* (type byte plus
/// an unterminated length-varint continuation byte), then silence with the socket held
/// open, so no EOF ever arrives — must be reaped by the deadline, counted as an
/// unrouted failure (never served), and must free its admission slot for a real
/// client. Shard sums stay exact with the failure in the unrouted remainder.
#[test]
fn half_open_partial_header_is_reaped_and_frees_the_slot() {
    let host: Vec<u64> = (0..1_200).collect();
    let server = SetxServer::builder(Setx::builder(&host).build().unwrap())
        .workers(1)
        .max_inflight_sessions(1)
        .timeouts(Some(Duration::from_millis(150)), None)
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    let mut half_open = TcpStream::connect(addr).unwrap();
    // Frame type 3, then 0x80: a continuation byte with no terminator — the frame
    // scanner will report "need more bytes" forever.
    half_open.write_all(&[3u8, 0x80]).unwrap();
    half_open.flush().unwrap();
    wait_until("the half-open connection to be reaped", || {
        let s = server.stats();
        s.unrouted_failed == 1 && s.inflight == 0
    });

    // The single admission slot is free again: a real client is served, not rejected.
    let client: Vec<u64> = (0..1_000).collect();
    let alice = Setx::builder(&client).build().unwrap();
    let report = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
    assert_eq!(report.intersection, client);
    drop(half_open);
    wait_until("the served session to be counted", || server.stats().sessions_served == 1);

    let stats = server.shutdown();
    assert_eq!(stats.sessions_served, 1, "{stats:?}");
    assert_eq!(stats.sessions_failed, 1, "{stats:?}");
    assert_eq!(stats.unrouted_failed, 1, "{stats:?}");
    // A stalled header is a dead peer, not wire garbage: no protocol fault.
    assert_eq!(stats.protocol_faults, 0, "{stats:?}");
    // Shard exactness: tenant failures plus the unrouted remainder equal the global,
    // and the served side shards exactly too.
    let tenant_failed: u64 = stats.tenants.iter().map(|t| t.sessions_failed).sum();
    assert_eq!(tenant_failed + stats.unrouted_failed, stats.sessions_failed);
    let tenant_served: u64 = stats.tenants.iter().map(|t| t.sessions_served).sum();
    assert_eq!(tenant_served, stats.sessions_served);
}

/// The orderly-close variant: a partial frame header followed by FIN. The server sees
/// EOF mid-header and must fail the connection promptly — no deadline wait involved,
/// so this passes even with generous timeouts.
#[test]
fn partial_header_then_eof_fails_without_waiting_for_the_deadline() {
    let host: Vec<u64> = (0..1_200).collect();
    let server = SetxServer::builder(Setx::builder(&host).build().unwrap())
        .workers(1)
        .timeouts(Some(Duration::from_secs(30)), None)
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    let mut goner = TcpStream::connect(addr).unwrap();
    goner.write_all(&[3u8, 0x80]).unwrap();
    goner.flush().unwrap();
    drop(goner); // FIN: the 30 s deadline must play no part
    wait_until("the EOF'd connection to be failed", || {
        let s = server.stats();
        s.unrouted_failed == 1 && s.inflight == 0
    });

    let client: Vec<u64> = (0..1_000).collect();
    let alice = Setx::builder(&client).build().unwrap();
    let report = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
    assert_eq!(report.intersection, client);
    wait_until("the served session to be counted", || server.stats().sessions_served == 1);
    let stats = server.shutdown();
    assert_eq!(stats.sessions_failed, 1, "{stats:?}");
    assert_eq!(stats.sessions_served, 1, "{stats:?}");
}

/// The acceptance criterion: a shared-geometry fleet (the loadgen default) reuses pooled
/// decoders for all but the cold starts — hit rate > 0.9 — with every intersection
/// verified.
#[test]
fn shared_geometry_fleet_hits_the_decoder_pool() {
    let cfg = LoadgenConfig {
        clients: 8,
        rounds: 4,
        common: 4_000,
        client_unique: 60,
        server_unique: 90,
        seed: 7,
        ..LoadgenConfig::default()
    };
    let (host, _, _) = cfg.workload();
    let server = SetxServer::builder(cfg.endpoint(&host).unwrap())
        .workers(2)
        .bind("127.0.0.1:0")
        .unwrap();
    let report = loadgen::run(server.local_addr(), &cfg);
    assert!(report.verified(), "loadgen failures: {:?}", report.failures);
    assert_eq!(report.sessions_ok, 32);
    wait_until("all sessions to be counted", || server.stats().sessions_served >= 32);
    let stats = server.shutdown();
    assert_eq!(stats.sessions_served, 32);
    assert_eq!(stats.sessions_failed, 0, "last failure: {stats:?}");
    assert!(stats.peak_workers <= 2);
    // With 2 workers on one shared geometry, only the cold-start builds miss.
    assert!(
        stats.pool_hit_rate() > 0.9,
        "decoder pool ineffective: hit rate {:.3} ({:?})",
        stats.pool_hit_rate(),
        stats.pool
    );
    assert!(stats.pool.hits + stats.pool.misses >= 32, "pool never consulted: {:?}", stats.pool);
}

/// Pool-off ablation still serves correctly (it just rebuilds decoders every session).
#[test]
fn pool_disabled_fleet_still_verifies() {
    let cfg = LoadgenConfig {
        clients: 4,
        rounds: 2,
        common: 2_000,
        client_unique: 40,
        server_unique: 50,
        seed: 9,
        ..LoadgenConfig::default()
    };
    let (host, _, _) = cfg.workload();
    let server = SetxServer::builder(cfg.endpoint(&host).unwrap())
        .workers(2)
        .pool_capacity(0)
        .bind("127.0.0.1:0")
        .unwrap();
    let report = loadgen::run(server.local_addr(), &cfg);
    assert!(report.verified(), "loadgen failures: {:?}", report.failures);
    let stats = server.shutdown();
    assert_eq!(stats.sessions_served, 8);
    assert_eq!(stats.pool.hits, 0, "disabled pool must never hit: {:?}", stats.pool);
}

/// The encode-side acceptance criterion: a shared-geometry fleet re-uses the host
/// sketch for all but the cold encode — SketchStore hit rate > 0.9 — and the pooled run
/// is byte-identical to the store-off ablation (per-phase wire bytes agree exactly),
/// with every intersection verified on both runs.
#[test]
fn shared_geometry_fleet_hits_the_sketch_store_and_matches_ablation_bytes() {
    let cfg = LoadgenConfig {
        clients: 8,
        rounds: 4,
        common: 4_000,
        client_unique: 60,
        server_unique: 90,
        seed: 13,
        ..LoadgenConfig::default()
    };
    let (host, _, _) = cfg.workload();
    let mut phase_bytes = Vec::new();
    let mut client_bytes = Vec::new();
    for store_on in [true, false] {
        let server = SetxServer::builder(cfg.endpoint(&host).unwrap())
            .workers(2)
            .sketch_store_capacity(if store_on { 8 } else { 0 })
            .bind("127.0.0.1:0")
            .unwrap();
        let report = loadgen::run(server.local_addr(), &cfg);
        assert!(report.verified(), "store_on={store_on} failures: {:?}", report.failures);
        assert_eq!(report.sessions_ok, 32);
        wait_until("all sessions to be counted", || server.stats().sessions_served >= 32);
        let stats = server.shutdown();
        assert_eq!(stats.sessions_served, 32, "store_on={store_on}: {stats:?}");
        if store_on {
            // One shared geometry: only the cold-start encode misses.
            assert!(
                stats.sketch_store_hit_rate() > 0.9,
                "sketch store ineffective: hit rate {:.3} ({:?})",
                stats.sketch_store_hit_rate(),
                stats.sketch_store
            );
            assert!(
                stats.sketch_store.hits + stats.sketch_store.misses >= 32,
                "store never consulted: {:?}",
                stats.sketch_store
            );
        } else {
            assert_eq!(stats.sketch_store.hits, 0, "disabled store must never hit");
        }
        phase_bytes.push(stats.phase_bytes);
        client_bytes.push(report.total_bytes);
    }
    // The store must be invisible on the wire: per-phase byte totals and the clients'
    // own accounting agree exactly between the store-on and store-off runs.
    assert_eq!(
        phase_bytes[0], phase_bytes[1],
        "store-on transcripts diverged from the store-off ablation"
    );
    assert_eq!(client_bytes[0], client_bytes[1]);
}

/// `replace_set` under a warmed store: resident sketches are maintained incrementally
/// (no full rebuild for a small diff), post-churn sessions still verify, and the store
/// keeps hitting — churn must not silently flush the encode-side cache.
#[test]
fn replace_set_maintains_resident_sketches_incrementally() {
    let cfg = LoadgenConfig {
        clients: 2,
        rounds: 2,
        common: 3_000,
        client_unique: 40,
        server_unique: 60,
        seed: 21,
        ..LoadgenConfig::default()
    };
    let (host, _, _) = cfg.workload();
    let server = SetxServer::builder(cfg.endpoint(&host).unwrap())
        .workers(2)
        .bind("127.0.0.1:0")
        .unwrap();
    let report = loadgen::run(server.local_addr(), &cfg);
    assert!(report.verified(), "pre-churn failures: {:?}", report.failures);
    wait_until("pre-churn sessions to finish", || server.stats().sessions_served >= 4);
    let warmed = server.stats().sketch_store;
    assert!(warmed.resident >= 1, "fleet must warm the store: {warmed:?}");

    // Small churn: swap 50 server-unique elements for 50 fresh ones. The diff (100) is
    // ≪ n ⇒ the §4 incremental path, and the host set *length* is unchanged, so the
    // handshake negotiates the identical geometry — the maintained resident sketch is
    // exactly what the next session checks out.
    let mut churned_host = host.clone();
    churned_host.truncate(host.len() - 50);
    churned_host.extend(900_000u64..900_050);
    server.replace_set(churned_host.clone());
    let churned = server.stats().sketch_store;
    assert!(
        churned.incremental_updates >= warmed.resident as u64,
        "resident sketches must be diff-maintained: {churned:?}"
    );
    assert_eq!(churned.full_rebuilds, 0, "a 100-element diff must not rebuild: {churned:?}");

    // A fresh client against the churned set: the maintained sketch serves the decode
    // (hits keep growing — the cache survived the churn), and the answer is exact, so
    // incremental maintenance demonstrably produced the true `M·1_host`.
    let client_set = report_client_set(&cfg);
    let alice = cfg.endpoint(&client_set).unwrap();
    let out = alice.run(&mut TcpTransport::connect(server.local_addr()).unwrap()).unwrap();
    let mut expected: Vec<u64> =
        client_set.iter().copied().filter(|id| churned_host.contains(id)).collect();
    expected.sort_unstable();
    assert_eq!(out.intersection, expected);
    wait_until("post-churn session to be counted", || server.stats().sessions_served >= 5);
    let after = server.shutdown().sketch_store;
    assert!(after.hits > churned.hits, "post-churn session must hit the store: {after:?}");
}

/// Client 0's set for `cfg` (the loadgen workload is deterministic).
fn report_client_set(cfg: &LoadgenConfig) -> Vec<u64> {
    let (_, clients, _) = cfg.workload();
    clients.into_iter().next().expect("at least one client")
}

/// Graceful shutdown drains the queue: sessions admitted before `shutdown` complete,
/// and their clients get correct answers.
#[test]
fn shutdown_drains_already_admitted_sessions() {
    let host: Vec<u64> = (0..2_000).collect();
    let server = SetxServer::builder(Setx::builder(&host).build().unwrap())
        .workers(1)
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();
    let clients = 4usize;
    std::thread::scope(|scope| {
        let host = &host;
        let handles: Vec<_> = (0..clients)
            .map(|i| {
                scope.spawn(move || {
                    let set: Vec<u64> = host[..1_800 - 10 * i].to_vec();
                    let alice = Setx::builder(&set).build().unwrap();
                    let report =
                        alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
                    assert_eq!(report.intersection, set, "client {i}");
                })
            })
            .collect();
        // Shut down as soon as everyone is routed — with one poller, most sessions are
        // still mid-protocol; the drain contract says they all finish anyway.
        wait_until("all clients to be routed", || {
            server.stats().sessions_accepted as usize == clients
        });
        let stats = server.shutdown();
        assert_eq!(stats.sessions_served as usize, clients, "queued sessions dropped");
        assert_eq!(stats.sessions_failed, 0);
        for h in handles {
            h.join().expect("client thread");
        }
    });
}

/// The scale acceptance criterion: ≥1k concurrent clients, round-robined over three
/// tenants, on exactly four poller threads — every intersection verified against its
/// tenant's expected common set, and the per-tenant shards summing to the globals.
#[test]
fn thousand_mixed_tenant_clients_on_four_pollers() {
    // ~3 fds per live session (client end, server end, slack); scale the fleet down
    // instead of failing where the soft limit cannot be raised.
    let limit = raise_nofile(4 * 1024 + 256);
    let clients = 1024usize.min((limit.saturating_sub(256) / 3) as usize).max(64);
    let cfg = LoadgenConfig {
        clients,
        rounds: 1,
        common: 600,
        client_unique: 10,
        server_unique: 20,
        seed: 31,
        tenants: 3,
        busy_retries: 6,
        ..LoadgenConfig::default()
    };
    let (hosts, _, _) = cfg.tenant_workload();
    let server = SetxServer::builder(cfg.endpoint(&hosts[0]).unwrap())
        .workers(4)
        .max_inflight_sessions(2 * clients)
        .timeouts(Some(Duration::from_secs(60)), Some(Duration::from_secs(60)))
        .bind("127.0.0.1:0")
        .unwrap();
    for (ns, host) in hosts.iter().enumerate().skip(1) {
        assert!(server.add_tenant(ns as u32, host.clone()));
    }

    let report = loadgen::run(server.local_addr(), &cfg);
    let shown: Vec<_> = report.failures.iter().take(5).collect();
    assert!(report.verified(), "{} failures, first: {shown:?}", report.failures.len());
    assert_eq!(report.sessions_ok, clients);

    wait_until("all sessions to be counted", || {
        server.stats().sessions_served as usize >= clients
    });
    let stats = server.shutdown();
    assert_eq!(stats.sessions_served as usize, clients);
    assert!(stats.peak_workers <= 4, "bounded pool violated: {}", stats.peak_workers);
    assert_eq!(
        stats.tenants.iter().map(|t| t.sessions_served).sum::<u64>(),
        stats.sessions_served,
        "tenant shards must sum to the global served count"
    );
    for t in &stats.tenants {
        assert!(t.sessions_served > 0, "tenant {} starved: {stats:?}", t.namespace);
    }
}

/// Wire-compat acceptance criterion: a namespace-less client (the PR-5-era frame
/// format — default-built clients never encode a namespace) lands on tenant 0, while a
/// `namespace(5)` client on the same listener is served tenant 5's set.
#[test]
fn namespace_less_client_interops_against_tenant_zero() {
    let host0: Vec<u64> = (0..1_500).collect();
    let host5: Vec<u64> = (1_000_000..1_001_500).collect();
    let server = SetxServer::builder(Setx::builder(&host0).build().unwrap())
        .workers(2)
        .tenant(5, host5.clone())
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    // Tenant-0 frames are byte-identical to the pre-tenancy encoding, so this is the
    // old-client interop path: absent namespace must mean tenant 0.
    let legacy_set: Vec<u64> = (0..1_400).collect();
    let legacy = Setx::builder(&legacy_set).build().unwrap();
    let report = legacy.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
    assert_eq!(report.intersection, legacy_set);

    let t5_set: Vec<u64> = (1_000_000..1_001_400).collect();
    let tenant5 = Setx::builder(&t5_set).namespace(5).build().unwrap();
    let report = tenant5.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
    assert_eq!(report.intersection, t5_set);

    wait_until("both sessions to be counted", || server.stats().sessions_served >= 2);
    let stats = server.shutdown();
    assert_eq!(stats.tenant(0).expect("tenant 0 stats").sessions_served, 1);
    assert_eq!(stats.tenant(5).expect("tenant 5 stats").sessions_served, 1);
    assert_eq!(stats.sessions_failed, 0, "last failure: {stats:?}");
}

/// Mixed-tenant fleet, cross-checked two ways: every client's wire intersection equals
/// its tenant's expected common set (already enforced by `verified()`), every client
/// also matches an in-memory `run_pair` reference on its tenant, and the per-tenant
/// stat shards sum exactly to the global counters — the stats invariant, end-to-end.
#[test]
fn mixed_tenant_fleet_matches_references_and_shards_sum_to_globals() {
    let cfg = LoadgenConfig {
        clients: 6,
        rounds: 2,
        common: 1_500,
        client_unique: 30,
        server_unique: 40,
        seed: 17,
        tenants: 2,
        ..LoadgenConfig::default()
    };
    let (hosts, client_sets, expected) = cfg.tenant_workload();
    let server = SetxServer::builder(cfg.endpoint(&hosts[0]).unwrap())
        .workers(2)
        .bind("127.0.0.1:0")
        .unwrap();
    assert!(server.add_tenant(1, hosts[1].clone()));

    let report = loadgen::run(server.local_addr(), &cfg);
    assert!(report.verified(), "failures: {:?}", report.failures);
    assert_eq!(report.sessions_ok, 12);

    // In-memory reference: each client run_pair'd against its own tenant's host set
    // must land on exactly that tenant's common block.
    for (i, set) in client_sets.iter().enumerate() {
        let t = i % 2;
        let alice = cfg.endpoint_for_tenant(set, t as u32).unwrap();
        let bob = cfg.endpoint_for_tenant(&hosts[t], t as u32).unwrap();
        let (rc, _) = alice.run_pair(&bob).expect("reference run");
        assert_eq!(rc.intersection, expected[t], "client {i} (tenant {t}) reference");
    }

    wait_until("all sessions to be counted", || server.stats().sessions_served >= 12);
    let stats = server.shutdown();
    assert_eq!(stats.sessions_served, 12);
    assert_eq!(stats.sessions_failed, 0, "last failure: {stats:?}");
    assert_eq!(
        stats.tenants.iter().map(|t| t.sessions_accepted).sum::<u64>(),
        stats.sessions_accepted
    );
    assert_eq!(
        stats.tenants.iter().map(|t| t.sessions_served).sum::<u64>(),
        stats.sessions_served
    );
    assert_eq!(
        stats.tenants.iter().map(|t| t.sessions_failed).sum::<u64>() + stats.unrouted_failed,
        stats.sessions_failed
    );
    assert_eq!(
        stats.tenants.iter().map(|t| t.sessions_rejected).sum::<u64>()
            + stats.unrouted_rejected,
        stats.sessions_rejected
    );
    for p in 0..4 {
        assert_eq!(
            stats.tenants.iter().map(|t| t.phase_bytes[p]).sum::<u64>(),
            stats.phase_bytes[p],
            "phase {p} bytes must shard exactly"
        );
    }
    for t in &stats.tenants {
        assert!(t.sessions_served >= 1, "tenant {} starved: {stats:?}", t.namespace);
    }
}

/// One raw HTTP/1.0 request against the metrics side socket; returns the full response.
fn http_get(addr: std::net::SocketAddr, request: &[u8]) -> String {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(request).unwrap();
    let mut response = String::new();
    conn.read_to_string(&mut response).unwrap();
    response
}

/// The live metrics endpoint: `metrics_addr("127.0.0.1:0")` starts an HTTP responder on
/// its own thread; a plain GET returns Prometheus text whose tenant series sum exactly
/// to the globals and whose histogram buckets are cumulative. Latency is recorded only
/// for *served* sessions, on both the tenant shard and the global histogram, so the
/// per-tenant counts shard the global count exactly.
#[test]
fn metrics_endpoint_serves_prometheus_text_with_exact_shards() {
    let cfg = LoadgenConfig {
        clients: 4,
        rounds: 2,
        common: 1_000,
        client_unique: 20,
        server_unique: 30,
        seed: 23,
        tenants: 2,
        ..LoadgenConfig::default()
    };
    let (hosts, _, _) = cfg.tenant_workload();
    let server = SetxServer::builder(cfg.endpoint(&hosts[0]).unwrap())
        .workers(2)
        .metrics_addr("127.0.0.1:0")
        .slow_session_threshold(Duration::from_secs(3_600))
        .bind("127.0.0.1:0")
        .unwrap();
    assert!(server.add_tenant(1, hosts[1].clone()));
    let maddr = server.metrics_addr().expect("metrics responder must be up");

    let report = loadgen::run(server.local_addr(), &cfg);
    assert!(report.verified(), "failures: {:?}", report.failures);
    wait_until("all sessions to be counted and drained", || {
        let s = server.stats();
        s.sessions_served >= 8 && s.inflight == 0
    });

    let response = http_get(maddr, b"GET /metrics HTTP/1.0\r\n\r\n");
    assert!(response.starts_with("HTTP/1.0 200 OK"), "bad status line: {response}");
    let body = response.split("\r\n\r\n").nth(1).expect("header/body split");

    // Every non-comment line is `name{labels} value` with a numeric value.
    for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let mut parts = line.split_whitespace();
        let name = parts.next().expect("metric name");
        let value = parts.next().unwrap_or_else(|| panic!("no value on: {line}"));
        assert!(name.starts_with("setx_"), "foreign metric name: {line}");
        assert!(value.parse::<f64>().is_ok(), "unparseable value on: {line}");
        assert_eq!(parts.next(), None, "trailing tokens on: {line}");
    }
    let metric = |name: &str| -> u64 {
        body.lines()
            .find(|l| l.split_whitespace().next() == Some(name))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("metric {name} missing"))
    };
    let label_sum = |prefix: &str| -> u64 {
        body.lines()
            .filter(|l| l.starts_with(prefix))
            .filter_map(|l| l.split_whitespace().nth(1))
            .filter_map(|v| v.parse::<u64>().ok())
            .sum()
    };
    assert_eq!(metric("setx_sessions_served"), 8);
    assert_eq!(metric("setx_inflight_sessions"), 0);
    assert_eq!(
        label_sum("setx_tenant_sessions_served{"),
        8,
        "tenant served series must sum to the global"
    );
    // Histogram exposition: buckets cumulative, `+Inf` equal to `_count`, and only
    // served sessions timed.
    let mut last = 0u64;
    let mut bucket_lines = 0usize;
    for line in body.lines().filter(|l| l.starts_with("setx_session_latency_ns_bucket{")) {
        let v: u64 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
        assert!(v >= last, "buckets must be cumulative: {line}");
        last = v;
        bucket_lines += 1;
    }
    assert!(bucket_lines >= 2, "histogram must expose buckets plus +Inf");
    assert_eq!(metric("setx_session_latency_ns_count"), 8, "only served sessions are timed");
    assert_eq!(last, 8, "+Inf bucket must equal _count");
    assert_eq!(
        label_sum("setx_tenant_session_latency_ns_count{"),
        8,
        "tenant latency histograms must shard the global count exactly"
    );

    // A non-GET request gets a 400 and the responder survives to serve the next probe.
    let bad = http_get(maddr, b"BOGUS\r\n\r\n");
    assert!(bad.starts_with("HTTP/1.0 400"), "non-GET must 400: {bad}");
    let again = http_get(maddr, b"GET / HTTP/1.0\r\n\r\n");
    assert!(again.contains("setx_sessions_served"), "endpoint died after the 400");

    let stats = server.shutdown();
    assert_eq!(stats.sessions_failed, 0, "{stats:?}");
    assert_eq!(stats.latency.count(), 8);
    assert!(stats.latency.quantile(0.99) >= stats.latency.quantile(0.5));
}

/// Coordinator mode end to end: a 3-party round through the daemon — two spokes join a
/// `multi_tenant` namespace over TCP, every party lands on the exact `∩ᵢSᵢ`, the
/// completed round is drained via `take_multi_reports`, and an ordinary two-party
/// client of the same namespace is still served afterwards.
#[test]
fn server_coordinator_mode_runs_an_n_party_round() {
    let sets = synth::overlap_n(3, 800, 25, 0xC0DE);
    let mut expected = sets[0].clone();
    for s in &sets[1..] {
        expected = synth::intersect(&expected, s);
    }
    let host0: Vec<u64> = (0..1_000).collect();
    let server = SetxServer::builder(Setx::builder(&host0).build().unwrap())
        .workers(2)
        .multi_tenant(9, sets[0].clone(), 3)
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    let spokes: Vec<_> = (1u32..3)
        .map(|id| {
            let set = sets[id as usize].clone();
            std::thread::spawn(move || {
                let cfg = *Setx::builder(&set).namespace(9).build().unwrap().config();
                join_round(addr, &cfg, set, id, 3)
            })
        })
        .collect();
    for (i, h) in spokes.into_iter().enumerate() {
        let r = h.join().expect("spoke thread").expect("spoke completes");
        assert_eq!(r.intersection, expected, "spoke {} answer", i + 1);
    }

    let mut reports = Vec::new();
    wait_until("the completed round to be drained", || {
        reports.extend(server.take_multi_reports(9));
        !reports.is_empty()
    });
    assert_eq!(reports.len(), 1, "exactly one completed round");
    let round = &reports[0];
    assert_eq!(round.intersection, expected);
    assert_eq!(round.completed(), 2);
    let per_party: usize = round.parties.iter().map(|p| p.total_bytes()).sum();
    assert_eq!(per_party, round.total_bytes(), "byte shards must sum");

    // The coordinator tenant still serves plain two-party clients against its set.
    let pair_set = sets[0][..700].to_vec();
    let alice = Setx::builder(&pair_set).namespace(9).build().unwrap();
    let report = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
    assert_eq!(report.intersection, synth::intersect(&pair_set, &sets[0]));

    wait_until("final session counts", || server.stats().sessions_served == 3);
    let stats = server.shutdown();
    assert_eq!(stats.sessions_served, 3, "2 spokes + 1 two-party client: {stats:?}");
    assert_eq!(stats.sessions_failed, 0, "{stats:?}");
}

/// A coordinator round whose roster never fills: with `session_timeout` = 400ms, the
/// join deadline closes the roster and the round runs with the parties actually
/// present — the daemon sibling of `net::host_round`'s deadline parameter.
#[test]
fn server_coordinator_join_deadline_runs_partial_roster() {
    let sets = synth::overlap_n(3, 400, 10, 0xDEAD);
    let host0: Vec<u64> = (0..500).collect();
    let server = SetxServer::builder(Setx::builder(&host0).build().unwrap())
        .workers(2)
        .multi_tenant(4, sets[0].clone(), 3)
        .timeouts(Some(Duration::from_millis(400)), Some(Duration::from_millis(400)))
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    // Only spoke 1 of the declared 3 parties ever joins.
    let cfg = *Setx::builder(&sets[1]).namespace(4).build().unwrap().config();
    let r = join_round(addr, &cfg, sets[1].clone(), 1, 3).expect("lone spoke completes");
    let expected = synth::intersect(&sets[0], &sets[1]);
    assert_eq!(r.intersection, expected);

    let mut reports = Vec::new();
    wait_until("the partial-roster round to be drained", || {
        reports.extend(server.take_multi_reports(4));
        !reports.is_empty()
    });
    assert_eq!(reports[0].intersection, expected);
    assert_eq!(reports[0].completed(), 1);
    assert_eq!(reports[0].parties.len(), 1, "only the joined spoke appears");
    let stats = server.shutdown();
    assert_eq!(stats.sessions_served, 1, "{stats:?}");
    assert_eq!(stats.sessions_failed, 0, "{stats:?}");
}

/// A joined-then-stalled spoke inside the daemon: the connection deadline drops it from
/// the round with a typed `PartyTimeout` while the other spokes complete the
/// intersection of the parties that stayed.
#[test]
fn server_coordinator_drops_a_stalled_spoke() {
    let sets = synth::overlap_n(4, 500, 12, 0x57A11);
    let host0: Vec<u64> = (0..600).collect();
    let server = SetxServer::builder(Setx::builder(&host0).build().unwrap())
        .workers(2)
        .multi_tenant(6, sets[0].clone(), 4)
        .timeouts(Some(Duration::from_millis(500)), Some(Duration::from_millis(500)))
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = server.local_addr();

    // Spoke 3 joins the roster with a real hello, then goes silent holding the socket.
    let stall_cfg = *Setx::builder(&sets[3]).namespace(6).build().unwrap().config();
    let stall_set = sets[3].clone();
    let staller = std::thread::spawn(move || {
        let mut party = Party::new(&stall_cfg, stall_set, 3, 4).unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        for m in party.start() {
            s.write_all(&m.to_bytes()).unwrap();
        }
        std::thread::sleep(Duration::from_millis(2_500));
        drop(s);
    });
    let live: Vec<_> = (1u32..3)
        .map(|id| {
            let set = sets[id as usize].clone();
            std::thread::spawn(move || {
                let cfg = *Setx::builder(&set).namespace(6).build().unwrap().config();
                join_round(addr, &cfg, set, id, 4)
            })
        })
        .collect();

    let expected = {
        let mut acc = sets[0].clone();
        for s in &sets[1..3] {
            acc = synth::intersect(&acc, s);
        }
        acc
    };
    for (i, h) in live.into_iter().enumerate() {
        let r = h.join().expect("spoke thread").expect("live spoke completes");
        assert_eq!(r.intersection, expected, "spoke {} answer", i + 1);
    }

    let mut reports = Vec::new();
    wait_until("the degraded round to be drained", || {
        reports.extend(server.take_multi_reports(6));
        !reports.is_empty()
    });
    let round = &reports[0];
    assert_eq!(round.intersection, expected);
    assert_eq!(round.completed(), 2);
    let dropped = round.parties.iter().find(|p| p.party == 3).unwrap();
    assert!(
        matches!(dropped.error, Some(MultiError::PartyTimeout { party: 3 })),
        "stalled spoke must surface PartyTimeout, got {:?}",
        dropped.error
    );
    staller.join().unwrap();
    let stats = server.shutdown();
    assert_eq!(stats.sessions_served, 2, "{stats:?}");
    assert_eq!(stats.sessions_failed, 1, "the dropped spoke: {stats:?}");
}
